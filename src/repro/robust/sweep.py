"""Resumable APSS sweeps: checkpointed block-ring with elastic resume.

The ring/halfring/checkerboard drivers in ``core.distributed`` run an entire
sweep as ONE traced ``fori_loop`` inside ``shard_map`` — maximally fast, but
a lost rank at step q-1 of an n²-scale job loses everything. This module
trades a little dispatch overhead for durability: the same block-pair
schedule, stepped from the HOST, with the accumulated ``Matches`` partials
and the sweep cursor checkpointed at step boundaries.

Schedule (the paper's ring, globalized): pad ``D`` to ``B`` row blocks of
``bn`` rows; step ``s`` scores every block pair ``(i, (i - s) mod B)`` in one
jitted batched contraction — over ``s ∈ [0, B)`` every ordered tile is
scored exactly once, so merging per-step :class:`Matches` via
``merge_matches`` (disjoint column ranges) is exact.

Why results are bit-identical across mesh shapes — the property the
reshaped-mesh resume test pins: the global computation is defined on the
full ``(B, bn, m)`` block tensor, and a mesh only changes *placement*
(``jnp.roll`` becomes a collective, the batched einsum runs
tile-parallel). Every per-tile contraction is the same shape with the same
operand order on every mesh, so step ``s`` from a checkpoint produces the
same bits whether the partials were resharded onto 8 devices, 3, or 1
(``elastic.reshard_tree`` handles placement; non-divisible shapes degrade
to replication).

Fault hooks (``robust.faults``): a kill fault between checkpoint steps
raises :class:`~repro.robust.faults.SweepKilled`; ``delay`` faults stretch
individual steps (feeding the :class:`~repro.distributed.straggler.StepTimer`
ledger); ``corrupt`` faults damage the traveling partials caravan. Recovery
from an evicted straggler rank = :func:`mesh_after_eviction` → a new sweep
over the same directory on the smaller mesh.
"""

from __future__ import annotations

import functools
import hashlib
import json
import os
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointManager
from repro.core.apss import pad_rows
from repro.core.matches import Matches, extract_matches, merge_matches
from repro.obs import trace
from repro.planner import telemetry

_META = "sweep_meta.json"


@functools.partial(jax.jit, static_argnames=("threshold", "k", "bn", "n"))
def _sweep_step(Db, values, indices, counts, s, *, threshold, k, bn, n):
    """One ring step: merge tiles ``(i, (i - s) mod B)`` for all i.

    ``s`` is traced (one compile serves every step); ``jnp.roll`` aligns
    partner blocks so ``rolled[i] = Db[(i - s) % B]``.
    """
    B = Db.shape[0]
    rolled = jnp.roll(Db, s, axis=0)
    S = jnp.einsum(
        "bim,bjm->bij", Db, rolled, preferred_element_type=jnp.float32
    )
    bi = jnp.arange(B, dtype=jnp.int32)
    row_off = bi * bn
    col_off = ((bi - s) % B) * bn

    def tile(scores, ro, co):
        valid = (co + jnp.arange(bn, dtype=jnp.int32)) < n
        return extract_matches(
            scores, threshold, k,
            row_offset=ro, col_offset=co,
            exclude_self=True, col_valid=valid,
        )

    tm = jax.vmap(tile)(S, row_off, col_off)
    step_matches = Matches(
        values=tm.values.reshape(B * bn, k),
        indices=tm.indices.reshape(B * bn, k),
        counts=tm.counts.reshape(B * bn),
    )
    return merge_matches(Matches(values, indices, counts), step_matches)


class ResumableSweep:
    """Checkpointed APSS self-join over a fixed dense corpus.

    ::

        sweep = ResumableSweep(D, threshold=0.35, k=16, directory=ckpt_dir)
        matches = sweep.run()            # may raise SweepKilled under faults
        ...
        matches = ResumableSweep(D, threshold=0.35, k=16,
                                 directory=ckpt_dir, mesh=smaller).run()
        # ^ resumes from the cursor, bit-identical to the uninterrupted run

    The checkpoint directory holds keep-last-k step dirs (the step number IS
    the sweep cursor) plus ``sweep_meta.json`` pinning (n, m, k, threshold,
    block size, corpus digest) — resuming against a different problem is a
    hard error, not silent garbage. Restore uses ``fallback=True``: a
    corrupt newest checkpoint costs one checkpoint window, not the job.
    """

    def __init__(
        self,
        D,
        *,
        threshold: float,
        k: int = 16,
        block_rows: int = 32,
        directory: str,
        mesh: Optional[Mesh] = None,
        axis_name: str = "data",
        keep: int = 3,
        checkpoint_every: int = 1,
        fault_plan=None,
        timer=None,
    ):
        D = np.asarray(D, dtype=np.float32)
        self.n, self.m = D.shape
        self.threshold = float(threshold)
        self.k = int(k)
        self.bn = int(block_rows)
        Dp, _ = pad_rows(jnp.asarray(D), self.bn)
        self.n_pad = int(Dp.shape[0])
        self.B = self.n_pad // self.bn
        self._Dhost = np.asarray(Dp).reshape(self.B, self.bn, self.m)
        self.directory = directory
        self.manager = CheckpointManager(directory, keep=keep)
        self.mesh = mesh
        self.axis_name = axis_name
        self.checkpoint_every = max(1, int(checkpoint_every))
        self.fault_plan = fault_plan
        self.timer = timer
        self.resumed_from: int | None = None
        self._write_or_check_meta()

    # -- meta --------------------------------------------------------------

    def _meta(self) -> dict:
        return {
            "n": self.n, "m": self.m, "k": self.k,
            "threshold": self.threshold, "block_rows": self.bn,
            "digest": hashlib.blake2b(
                self._Dhost.tobytes(), digest_size=16
            ).hexdigest(),
        }

    def _write_or_check_meta(self) -> None:
        path = os.path.join(self.directory, _META)
        meta = self._meta()
        if os.path.exists(path):
            with open(path) as f:
                on_disk = json.load(f)
            if on_disk != meta:
                diff = {
                    key for key in meta
                    if on_disk.get(key) != meta[key]
                }
                raise ValueError(
                    f"sweep meta mismatch in {self.directory}: {sorted(diff)} "
                    f"differ — refusing to resume a different problem"
                )
            return
        with open(path, "w") as f:
            json.dump(meta, f)

    # -- placement ---------------------------------------------------------

    def _axis_size(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape[self.axis_name]

    def _match_specs(self):
        """PartitionSpecs for the partials tree (row-sharded when the row
        count divides the mesh axis, else replicated — same spec family at
        every scale, per the elastic contract)."""
        p = self._axis_size()
        ax = self.axis_name if (p > 1 and self.n_pad % p == 0) else None
        return {
            "values": P(ax, None), "indices": P(ax, None), "counts": P(ax),
        }

    def _place_partials(self, host_tree: dict) -> dict:
        if self.mesh is None:
            return {kk: jnp.asarray(v) for kk, v in host_tree.items()}
        from repro.distributed.elastic import reshard_tree

        return reshard_tree(host_tree, self._match_specs(), self.mesh)

    def _place_data(self):
        Db = jnp.asarray(self._Dhost)
        if self.mesh is not None:
            p = self._axis_size()
            spec = P(self.axis_name, None, None) if self.B % p == 0 else P()
            Db = jax.device_put(Db, NamedSharding(self.mesh, spec))
        return Db

    def _fresh_host(self) -> dict:
        return {
            "values": np.full((self.n_pad, self.k), -np.inf, np.float32),
            "indices": np.full((self.n_pad, self.k), -1, np.int32),
            "counts": np.zeros((self.n_pad,), np.int32),
        }

    # -- the sweep ---------------------------------------------------------

    def run(self, *, resume: bool = True) -> Matches:
        """Run (or resume) the sweep to completion; returns global Matches.

        Under an armed kill fault this raises ``SweepKilled`` part-way —
        every completed checkpoint boundary is already durable, so a fresh
        ``ResumableSweep`` over the same directory (any mesh) continues.
        """
        start = 0
        host = None
        if resume:
            host, step = self.manager.restore(
                like=self._fresh_host(), fallback=True
            )
            if host is not None:
                start = int(step)
                self.resumed_from = start
                telemetry.incr("sweep.resumed_steps", start)
        if host is None:
            host = self._fresh_host()
        state = self._place_partials(host)
        Db = self._place_data()
        plan = self.fault_plan

        for s in range(start, self.B):
            with trace.span("sweep/step", i=s):
                if plan is not None:
                    plan.kill_point(s)
                    plan.delay("sweep", step=s)
                if self.timer is not None:
                    self.timer.start()
                merged = _sweep_step(
                    Db, state["values"], state["indices"], state["counts"],
                    jnp.int32(s),
                    threshold=self.threshold, k=self.k, bn=self.bn, n=self.n,
                )
                state = {
                    "values": merged.values,
                    "indices": merged.indices,
                    "counts": merged.counts,
                }
                jax.block_until_ready(state["values"])
                if self.timer is not None:
                    self.timer.stop(rank=0)
                if plan is not None and plan.armed("corrupt", "sweep.caravan"):
                    state["values"] = jnp.asarray(
                        plan.corrupt_array(np.asarray(state["values"]), step=s)
                    )
                if (s + 1) % self.checkpoint_every == 0 or s + 1 == self.B:
                    self.manager.save(
                        {kk: np.asarray(v) for kk, v in state.items()},
                        step=s + 1,
                    )
                    telemetry.incr("sweep.checkpoints")

        return Matches(
            values=state["values"][: self.n],
            indices=state["indices"][: self.n],
            counts=state["counts"][: self.n],
        )

    def resume_on(self, new_mesh: Optional[Mesh]) -> "ResumableSweep":
        """A sweep over the same directory/problem placed on ``new_mesh`` —
        the elastic recovery path after rank loss or straggler eviction."""
        return ResumableSweep(
            self._Dhost.reshape(self.n_pad, self.m)[: self.n],
            threshold=self.threshold, k=self.k, block_rows=self.bn,
            directory=self.directory, mesh=new_mesh,
            axis_name=self.axis_name, keep=self.manager.keep,
            checkpoint_every=self.checkpoint_every,
            fault_plan=self.fault_plan, timer=self.timer,
        )


def mesh_after_eviction(
    mesh: Mesh, report, *, axis_name: str = "data"
) -> Mesh:
    """Shrink a mesh by dropping evicted ranks (``StragglerReport.evict``).

    Standard elastic policy (``distributed.elastic``): losing ranks costs
    parallelism, never correctness — the survivors form a 1-D mesh and the
    resumed sweep's partials are resharded onto it (or replicated when the
    shapes stop dividing). Returns ``mesh`` unchanged when nothing evicts.
    """
    if not report.evict:
        return mesh
    devs = list(np.asarray(mesh.devices).reshape(-1))
    bad = set(report.evict)
    keep = [d for i, d in enumerate(devs) if i not in bad]
    if not keep:
        raise ValueError("straggler report evicts every rank — cannot shrink")
    return Mesh(np.array(keep), (axis_name,))
