"""APSS → similarity graph → GNN: the paper's "similarity graph as a
computational kernel" application, end to end.

Builds an ε-neighborhood graph over a synthetic corpus with the APSS core,
feeds it to the GAT architecture (gat-cora assigned config family), and
trains node classification for a few hundred steps.

    PYTHONPATH=src python examples/similarity_graph.py
"""

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.apss import apss_blocked, normalize_rows
from repro.core.graph import coo_to_padded_edges, matches_to_coo
from repro.launch.train import make_gat_train_step
from repro.models import gnn
from repro.optim import adamw_init


def make_clustered_corpus(n_per_class=64, n_classes=5, d=128, seed=0):
    """Gaussian clusters → rows with class structure the graph can reveal."""
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((n_classes, d)) * 2.0
    X, y = [], []
    for c in range(n_classes):
        X.append(centers[c] + rng.standard_normal((n_per_class, d)))
        y.append(np.full(n_per_class, c))
    X = np.concatenate(X).astype(np.float32)
    y = np.concatenate(y).astype(np.int32)
    perm = rng.permutation(len(X))
    return X[perm], y[perm]


def main() -> None:
    X, y = make_clustered_corpus()
    n = len(X)
    D = np.asarray(normalize_rows(jnp.asarray(X)))

    # 1. similarity graph via the paper's algorithm
    t = 0.55
    matches = apss_blocked(jnp.asarray(D), t, k=32, block_rows=64)
    rows, cols, w = matches_to_coo(matches)
    print(f"APSS: {len(rows)} edges at t={t} over {n} vectors")

    src, dst, wts, mask = coo_to_padded_edges(
        rows, cols, w, max_edges=4 * len(rows) + 2 * n,
        add_reverse=True, add_self_loops_n=n,
    )

    # 2. GAT on the similarity graph
    cfg = gnn.GATConfig(name="gat-simgraph", d_feat=X.shape[1], n_classes=5,
                        d_hidden=8, n_heads=4)
    params = gnn.init_gat(jax.random.key(0), cfg)
    opt = adamw_init(params)
    label_mask = (np.random.default_rng(1).random(n) < 0.3).astype(np.float32)
    batch = {
        "features": jnp.asarray(X),
        "edge_src": jnp.asarray(src),
        "edge_dst": jnp.asarray(dst),
        "edge_mask": jnp.asarray(mask),
        "labels": jnp.asarray(y),
        "label_mask": jnp.asarray(label_mask),
    }
    step = jax.jit(make_gat_train_step(cfg))
    for s in range(200):
        params, opt, metrics = step(params, opt, batch)
        if s % 50 == 0 or s == 199:
            print(f"step {s}: loss={float(metrics['loss']):.4f} "
                  f"train_acc={float(metrics['acc']):.3f}")

    # eval on the unlabeled nodes
    logits = gnn.gat_forward(params, cfg, batch)
    pred = np.asarray(jnp.argmax(logits, -1))
    test = label_mask == 0
    acc = (pred[test] == y[test]).mean()
    print(f"held-out accuracy via similarity-graph GAT: {acc:.3f}")
    assert acc > 0.5, "similarity graph should beat chance by a wide margin"


if __name__ == "__main__":
    main()
