"""Quickstart: the paper's problem end-to-end on one machine.

1. Build a power-law corpus with the statistics of the paper's datasets.
2. Run the (sequential) APSS — the all-pairs-0-array analogue.
3. Run every distributed variant on 8 virtual devices and verify they
   agree exactly with the oracle (1-D horizontal / 1-D vertical with local
   pruning / 2-D).
4. Build the similarity graph (the paper's headline output).

    PYTHONPATH=src python examples/quickstart.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.apss import apss_reference  # noqa: E402
from repro.core.distributed import (  # noqa: E402
    apss_2d,
    apss_horizontal,
    apss_vertical,
)
from repro.core.graph import match_set, matches_to_coo  # noqa: E402
from repro.data.synthetic import corpus_stats, synthetic_corpus  # noqa: E402


def main() -> None:
    # 1. paper-style data (Zipf dimension popularity, unit-norm rows)
    D_np = synthetic_corpus(n=512, m=2048, avg_nnz=60, seed=0)
    print("corpus:", corpus_stats(D_np).row())
    D = jnp.asarray(D_np)
    t, k = 0.4, 32

    # 2. sequential oracle
    ref = jax.jit(lambda d: apss_reference(d, t, k))(D)
    print(f"oracle: {int(ref.counts.sum())//2} unordered matches at t={t}")

    # 3. the paper's three distributions
    from repro.compat import make_mesh

    mesh_h = make_mesh((8,), ("data",))
    mesh_v = make_mesh((8,), ("model",))
    mesh_2d = make_mesh((4, 2), ("data", "model"))

    variants = {
        "1-D horizontal (ring)": lambda d: apss_horizontal(
            d, t, k, mesh_h, schedule="ring", block_rows=64),
        "1-D horizontal (half-ring)": lambda d: apss_horizontal(
            d, t, k, mesh_h, schedule="halfring", block_rows=64),
        "1-D vertical (local pruning)": lambda d: apss_vertical(
            d, t, k, mesh_v, accumulation="compressed", block_rows=64,
            candidate_capacity=128),
        "2-D checkerboard": lambda d: apss_2d(
            d, t, k, mesh_2d, accumulation="compressed", block_rows=64,
            candidate_capacity=128),
    }
    want = match_set(ref)
    for name, fn in variants.items():
        got = jax.jit(fn)(D)
        ok = match_set(got) == want
        print(f"  {name:32s} -> {'EXACT' if ok else 'MISMATCH'}")

    # 4. similarity graph
    rows, cols, w = matches_to_coo(ref)
    print(f"similarity graph: {len(rows)} edges, mean weight {w.mean():.3f}")


if __name__ == "__main__":
    main()
