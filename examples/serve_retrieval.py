"""Batched retrieval serving: two-tower model + APSS-backed candidate
scoring (the retrieval_cand shape at reduced scale), plus the LM decode
server for comparison.

    PYTHONPATH=src python examples/serve_retrieval.py
"""

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.data import RecsysPipeline
from repro.models import recsys


def main() -> None:
    cfg = get_arch("two-tower-retrieval").make_smoke_config()
    params = recsys.init_two_tower(jax.random.key(0), cfg)
    pipe = RecsysPipeline(
        n_items=cfg.n_items, batch_size=1, history_len=cfg.history_len,
        n_user_fields=cfg.n_user_fields, user_vocab=cfg.user_vocab,
        kind="two-tower",
    )
    candidates = jnp.arange(cfg.n_items)

    retrieve = jax.jit(
        lambda p, b, c: recsys.retrieval_scores(p, cfg, b, c, k=16)
    )

    # warm + serve a few requests
    batch = jax.tree.map(jnp.asarray, pipe.get_batch(0))
    jax.block_until_ready(retrieve(params, batch, candidates))
    t0 = time.perf_counter()
    n_req = 16
    for r in range(n_req):
        batch = jax.tree.map(jnp.asarray, pipe.get_batch(r))
        m = retrieve(params, batch, candidates)
        if r < 3:
            top = np.asarray(m.indices[0, :5])
            sc = np.asarray(m.values[0, :5])
            print(f"request {r}: top5 items {top} scores {np.round(sc, 3)}")
    dt = time.perf_counter() - t0
    print(f"[serve] {n_req} retrieval requests over {cfg.n_items} candidates "
          f"in {dt:.2f}s ({n_req/dt:.1f} req/s on CPU)")

    # pointwise ranking path (serve_p99 shape, reduced)
    score = jax.jit(lambda p, b: recsys.two_tower_score(p, cfg, b))
    rp = RecsysPipeline(
        n_items=cfg.n_items, batch_size=64, history_len=cfg.history_len,
        n_user_fields=cfg.n_user_fields, user_vocab=cfg.user_vocab,
        kind="two-tower",
    )
    b = jax.tree.map(jnp.asarray, rp.get_batch(0))
    s = score(params, b)
    print(f"[serve] pointwise batch=64 scores: mean={float(s.mean()):.3f}")


if __name__ == "__main__":
    main()
