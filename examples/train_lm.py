"""End-to-end LM training driver (reduced config, a few hundred steps).

Exercises the full production path on CPU: deterministic data pipeline →
APSS dedup of the input stream → jit'd train step (loss/grad/AdamW) → async
checkpoints with keep-last-k → auto-resume. This is deliverable (b)'s
"train a ~100M-class model for a few hundred steps" driver at a CPU-
friendly scale; the same code runs the full configs through
``launch/train.py`` on a real mesh.

    PYTHONPATH=src python examples/train_lm.py --steps 120
"""

import argparse
import os
import tempfile

import numpy as np

from repro.launch.train import train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    ckpt = args.ckpt_dir or os.path.join(tempfile.mkdtemp(), "ckpt")
    print(f"[example] training smoke-scale {args.arch} for {args.steps} steps")
    out = train_loop(
        arch=args.arch, steps=args.steps, ckpt_dir=ckpt, ckpt_every=40,
        log_every=20,
    )
    print("[example] final metrics:", out)
    assert np.isfinite(out["loss"])
    # resume demo: continue 20 more steps from the checkpoint
    out2 = train_loop(
        arch=args.arch, steps=args.steps + 20, ckpt_dir=ckpt, ckpt_every=40,
        log_every=20,
    )
    print("[example] resumed +20 steps:", out2)


if __name__ == "__main__":
    main()
