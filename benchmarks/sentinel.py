"""Perf-regression sentinel: bench history + noise-aware regression gate.

The per-PR artifact (``BENCH_apss.json``) is a snapshot; a regression is a
statement about a *sequence* of snapshots. This module keeps that sequence
in ``BENCH_history.jsonl`` — one provenance-keyed record per bench run
(git sha, timestamp, device kind, jax version, flat metric dict) — and
gates the current run against a **rolling-median baseline** of the last
``window`` records:

- ``record``: extract the stable scalar metrics from an artifact and
  append one JSONL line (idempotent per sha: re-recording the same git
  sha replaces the previous record rather than double-counting it in its
  own baseline);
- ``check``: flag any metric whose current value exceeds
  ``tolerance ×`` the rolling median of prior records — inverted for the
  throughput lanes in :data:`HIGHER_IS_BETTER` (``serving.qps_batch64``),
  where the regression is a drop below ``median / tolerance``. The median
  (not
  the last run) is the baseline precisely because single CI runs are
  noisy — one slow machine poisons a last-run baseline but moves a
  5-run median by nothing. With fewer than ``min_records`` prior
  records the check PASSES (no baseline yet, nothing to regress from).

Only same-device-kind records are compared: a history that mixes CPU and
TPU runs must not gate one against the other.

CLI (wired into CI after the bench smokes)::

    python -m benchmarks.sentinel record --artifact BENCH_apss.json
    python -m benchmarks.sentinel check  --artifact BENCH_apss.json

``check`` exits 1 on regression and prints the offending metrics with
their baselines.
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import sys

DEFAULT_HISTORY = "BENCH_history.jsonl"
DEFAULT_WINDOW = 5
DEFAULT_TOLERANCE = 1.5
DEFAULT_MIN_RECORDS = 1

# Metrics where a DROP is the regression (throughput lanes). Everything
# else is lower-is-better latency/cost; for these the gate inverts:
# flag when value < baseline / tolerance.
HIGHER_IS_BETTER = frozenset({"serving.qps_batch64"})


def extract_metrics(doc: dict) -> dict:
    """Flatten the stable scalar metrics out of a bench artifact.

    Keys are dotted paths; every value is a float in the lane's native
    unit (µs for timing lanes, seconds for the mutable delta lane, QPS
    for the serving throughput lane — see :data:`HIGHER_IS_BETTER`).
    Lanes absent from the artifact are simply skipped — partial artifacts
    (``--only``-style runs) still record what they measured.
    """
    out: dict[str, float] = {}
    for name, v in (doc.get("variants") or {}).items():
        if isinstance(v, dict) and "us_per_call" in v:
            out[f"variants.{name}.us_per_call"] = float(v["us_per_call"])
    sweep = doc.get("sparse_sweep") or {}
    for e in sweep.get("entries", ()):
        d = e.get("density_requested", e.get("density"))
        tag = f"sparse_sweep.d={d}"
        for name, v in (e.get("variants") or {}).items():
            if isinstance(v, dict) and "us_per_call" in v:
                out[f"{tag}.{name}.us_per_call"] = float(v["us_per_call"])
    serving = doc.get("serving") or {}
    if "index_build_us" in serving:
        out["serving.index_build_us"] = float(serving["index_build_us"])
    for b, v in (serving.get("batches") or {}).items():
        if isinstance(v, dict) and "us_per_query" in v:
            out[f"serving.batch={b}.us_per_query"] = float(v["us_per_query"])
    if "qps_batch64" in serving:
        out["serving.qps_batch64"] = float(serving["qps_batch64"])
    if "p99_us" in serving:
        out["serving.p99_us"] = float(serving["p99_us"])
    mutable = doc.get("mutable") or {}
    for e in mutable.get("deltas", ()):
        if "append_s" in e:
            out[f"mutable.delta={e.get('delta')}.append_s"] = float(
                e["append_s"]
            )
    return out


def _history_record(doc: dict) -> dict:
    prov = doc.get("provenance") or {}
    return {
        "git_sha": prov.get("git_sha", "unknown"),
        "timestamp": prov.get("timestamp", "unknown"),
        "device_kind": prov.get("device_kind", "unknown"),
        "jax_version": prov.get("jax_version", "unknown"),
        "metrics": extract_metrics(doc),
    }


def load_history(path: str) -> list:
    if not os.path.exists(path):
        return []
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def _write_history(path: str, records: list) -> None:
    with open(path, "w") as f:
        for r in records:
            f.write(json.dumps(r, sort_keys=True) + "\n")


def record(doc: dict, history_path: str = DEFAULT_HISTORY) -> dict:
    """Append this artifact's record to the history (replacing any prior
    record with the same git sha — a re-run supersedes, never inflates
    its own baseline). Returns the appended record."""
    rec = _history_record(doc)
    history = load_history(history_path)
    history = [r for r in history if r.get("git_sha") != rec["git_sha"]]
    history.append(rec)
    _write_history(history_path, history)
    return rec


def check(
    doc: dict,
    history_path: str = DEFAULT_HISTORY,
    *,
    window: int = DEFAULT_WINDOW,
    tolerance: float = DEFAULT_TOLERANCE,
    min_records: int = DEFAULT_MIN_RECORDS,
) -> dict:
    """Gate ``doc`` against the rolling-median baseline (module doc).

    Returns ``{"ok", "checked", "skipped", "baseline_records",
    "regressions": [{metric, current, baseline, ratio}, ...]}``. The
    current run's own history record (matched by git sha) is excluded
    from its baseline.
    """
    rec = _history_record(doc)
    current = rec["metrics"]
    prior = [
        r for r in load_history(history_path)
        if r.get("git_sha") != rec["git_sha"]
        and r.get("device_kind") == rec["device_kind"]
    ][-window:]
    if len(prior) < min_records:
        return {
            "ok": True, "checked": 0, "skipped": len(current),
            "baseline_records": len(prior), "regressions": [],
        }
    regressions = []
    checked = skipped = 0
    for metric, value in sorted(current.items()):
        samples = [
            r["metrics"][metric] for r in prior if metric in r["metrics"]
        ]
        if not samples:
            skipped += 1
            continue
        checked += 1
        baseline = statistics.median(samples)
        if baseline <= 0:
            continue
        if metric in HIGHER_IS_BETTER:
            # throughput: a drop below baseline/tolerance is the regression
            bad = value < baseline / tolerance
            ratio = baseline / value if value > 0 else float("inf")
        else:
            bad = value > tolerance * baseline
            ratio = value / baseline
        if bad:
            regressions.append({
                "metric": metric,
                "current": value,
                "baseline": baseline,
                "ratio": ratio,
            })
    return {
        "ok": not regressions,
        "checked": checked,
        "skipped": skipped,
        "baseline_records": len(prior),
        "regressions": regressions,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="bench history recorder + perf-regression gate"
    )
    ap.add_argument("command", choices=("record", "check"))
    ap.add_argument("--artifact", default="BENCH_apss.json")
    ap.add_argument("--history", default=DEFAULT_HISTORY)
    ap.add_argument("--window", type=int, default=DEFAULT_WINDOW)
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE)
    ap.add_argument("--min-records", type=int, default=DEFAULT_MIN_RECORDS)
    args = ap.parse_args(argv)

    with open(args.artifact) as f:
        doc = json.load(f)

    if args.command == "record":
        rec = record(doc, args.history)
        print(
            f"recorded {len(rec['metrics'])} metrics for "
            f"{rec['git_sha'][:12]} ({rec['device_kind']}) -> {args.history}"
        )
        return 0

    result = check(
        doc, args.history, window=args.window,
        tolerance=args.tolerance, min_records=args.min_records,
    )
    if result["baseline_records"] < args.min_records:
        print(
            f"sentinel: PASS (only {result['baseline_records']} baseline "
            f"records, need {args.min_records})"
        )
        return 0
    for r in result["regressions"]:
        print(
            f"REGRESSION {r['metric']}: {r['current']:.1f} vs median "
            f"{r['baseline']:.1f} ({r['ratio']:.2f}x > "
            f"{args.tolerance:.2f}x)",
            file=sys.stderr,
        )
    print(
        f"sentinel: {'PASS' if result['ok'] else 'FAIL'} "
        f"({result['checked']} metrics vs {result['baseline_records']} "
        f"records, {len(result['regressions'])} regressions)"
    )
    return 0 if result["ok"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
