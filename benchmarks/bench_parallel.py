"""Paper Figs 3-6: horizontal vs vertical vs 2-D distribution comparison.

8 virtual CPU devices share one socket, so wall-clock "speedup" is not
meaningful here; the scaling evidence is per-device work (HLO FLOPs from
cost_analysis — exactly 1/p for ideal distributions) plus per-device
collective bytes (the paper's communication-volume profiles). Wall time is
reported for completeness. Real-mesh scaling lives in the roofline table
(EXPERIMENTS.md §Roofline).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import bench_corpus, row, time_fn
from repro.core.apss import apss_blocked
from repro.core.distributed import apss_2d, apss_horizontal, apss_vertical

T, K = 0.4, 32


def _flops_and_coll(fn, D):
    """Loop-aware per-device FLOPs and collective link bytes."""
    from repro.launch.hlo_analysis import analyze

    comp = jax.jit(fn).lower(D).compile()
    a = analyze(comp.as_text())
    return a["flops"], a["link_bytes"]


def run(lines: list) -> None:
    D = jnp.asarray(bench_corpus(1024, 768))

    seq = jax.jit(functools.partial(apss_blocked, threshold=T, k=K, block_rows=256))
    us0 = time_fn(seq, D)
    fl0, _ = _flops_and_coll(
        functools.partial(apss_blocked, threshold=T, k=K, block_rows=256), D
    )
    lines.append(row("parallel/sequential", us0, f"flops_dev={fl0:.2e}"))

    from repro.compat import make_mesh

    mesh_h = make_mesh((8,), ("data",))
    mesh_v = make_mesh((8,), ("model",))
    mesh_2d = make_mesh((4, 2), ("data", "model"))

    cases = {
        "horizontal-allgather": functools.partial(
            apss_horizontal, threshold=T, k=K, mesh=mesh_h,
            schedule="allgather", block_rows=128),
        "horizontal-ring": functools.partial(
            apss_horizontal, threshold=T, k=K, mesh=mesh_h,
            schedule="ring", block_rows=128),
        "horizontal-halfring": functools.partial(
            apss_horizontal, threshold=T, k=K, mesh=mesh_h,
            schedule="halfring", block_rows=128),
        "vertical-compressed": functools.partial(
            apss_vertical, threshold=T, k=K, mesh=mesh_v,
            accumulation="compressed", block_rows=128,
            candidate_capacity=256),
        "2d-compressed": functools.partial(
            apss_2d, threshold=T, k=K, mesh=mesh_2d,
            accumulation="compressed", block_rows=128,
            candidate_capacity=256),
        # Fused-kernel scoring inside the ring schedules: the score tile
        # never reaches HBM and each step's extraction is O(rows·k).
        "horizontal-ring-fused": functools.partial(
            apss_horizontal, threshold=T, k=K, mesh=mesh_h,
            schedule="ring", block_rows=128, use_kernel=True),
        "horizontal-halfring-fused": functools.partial(
            apss_horizontal, threshold=T, k=K, mesh=mesh_h,
            schedule="halfring", block_rows=128, use_kernel=True),
    }
    for name, fn in cases.items():
        us = time_fn(jax.jit(fn), D)
        fl, cb = _flops_and_coll(fn, D)
        lines.append(row(
            f"parallel/{name}", us,
            f"flops_dev={fl:.2e};work_scaling={fl0/max(fl,1):.1f}x;"
            f"coll_bytes={cb:.0f}",
        ))

    # Sparse distribution lanes (the paper's Table-1 regime on the same
    # shapes): the 1-D CSR ring and the composed 2-D checkerboard. The 2-D
    # sparse entry is host-staged (shard_dims pre-split), so no jit/HLO
    # pass — modeled per-device FLOPs and collective bytes come from the
    # telemetry record (the same executed hop formulas the planner prices).
    from repro.data.sparse import sparse_zipfian_corpus
    from repro.planner import CommLog

    sp = sparse_zipfian_corpus(1024, 768, 12.0, seed=1)
    sparse_cases = {
        "horizontal-ring-sparse": functools.partial(
            apss_horizontal, threshold=T, k=K, mesh=mesh_h,
            axis_name="data", schedule="ring", block_rows=128),
        "2d-sparse-allreduce": functools.partial(
            apss_2d, threshold=T, k=K, mesh=mesh_2d,
            accumulation="allreduce", block_rows=128),
        "2d-sparse-compressed": functools.partial(
            apss_2d, threshold=T, k=K, mesh=mesh_2d,
            accumulation="compressed", block_rows=128,
            candidate_capacity=256),
    }
    for name, fn in sparse_cases.items():
        with CommLog() as log:
            us = time_fn(fn, sp, warmup=1, iters=3)
        rec = log.records[0]
        lines.append(row(
            f"parallel/{name}", us,
            f"flops_dev={rec.flops:.2e};"
            f"work_scaling={fl0/max(rec.flops,1):.1f}x;"
            f"coll_bytes={rec.wire_bytes}",
        ))
