"""Paper Tables 5-6: vertical-accumulation variants — local pruning's effect
on candidates and communication volume.

Paper columns → our columns:
  Scores  → words communicated per query block (dense vs compressed)
  Cand    → avg/max local candidates at t/p (exact, measured)
The HLO-derived per-device collective bytes (same parser as the roofline)
give the 'communication time' analogue without wall-clock noise.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import bench_corpus, row, time_fn
from repro.core.distributed import apss_vertical
from repro.core.pruning import local_threshold

T, K = 0.4, 32


def _mesh(p):
    from repro.compat import make_mesh

    return make_mesh((p,), ("model",))


def _collective_bytes(fn, D):
    """Loop-aware per-device collective link bytes (hlo_analysis)."""
    from repro.launch.hlo_analysis import analyze

    hlo = jax.jit(fn).lower(D).compile().as_text()
    return analyze(hlo)["link_bytes"]


def run(lines: list) -> None:
    # n/capacity ratio sized so compaction can show its 10-100× volume win
    # (paper Tables 5-6); tiny corpora make the candidate union ≈ n.
    Dn = bench_corpus(2048, 768)
    D = jnp.asarray(Dn)
    n = D.shape[0]

    for p in (2, 4, 8):
        mesh = _mesh(p)
        # measured local candidate statistics at t/p (paper's Cand columns)
        t_loc = float(local_threshold(T, p))
        cols = np.array_split(np.arange(D.shape[1]), p)
        cand_counts = []
        for c in cols:
            A = Dn[:, c] @ Dn[:, c].T
            cand_counts.append((A >= t_loc).sum(1))
        cand = np.stack(cand_counts)
        for acc, name in (
            ("allreduce", "noopt"),
            ("scatter", "flat-scatter"),
            ("compressed", "localpruning"),
            ("recursive", "recursive"),
        ):
            fn = functools.partial(
                apss_vertical, threshold=T, k=K, mesh=mesh,
                accumulation=acc, block_rows=256, candidate_capacity=64,
            )
            us = time_fn(jax.jit(fn), D, iters=3)
            cbytes = _collective_bytes(fn, D)
            derived = (
                f"p={p};coll_bytes={cbytes:.0f};"
                f"cand_avg={cand.mean():.0f};cand_max={cand.max()}"
            )
            lines.append(row(f"pruning/vertical-{name}-p{p}", us, derived))
