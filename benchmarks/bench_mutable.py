"""Mutable-index bench: append+delta-join cost vs full rebuild.

The live-corpus acceptance bar (ISSUE 7): per-append cost must scale with
the DELTA, not the corpus — appending ``delta`` rows to an ``n``-row
``MutableAPSSIndex`` (WAL-less) is timed against rebuilding the whole
``n + delta`` index from scratch, across delta sizes ``n/64 → n/4``. The
CI gate (``check_schema.check_mutable``) requires ≥ 5× speedup at
delta ≤ n/16.

Each delta size gets a fresh base index and a warmup append on a scratch
twin so trace time is excluded from both sides (the rebuild side reuses
the same compiled delta-join shapes). Run standalone to merge a
``mutable`` section into BENCH_apss.json:

    PYTHONPATH=src python -m benchmarks.bench_mutable --json [PATH] [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.serving import MutableAPSSIndex


def _timed(fn, *, iters: int) -> float:
    """Median wall seconds. No jit-level warmup here — each call mutates
    state, so callers pass pre-warmed (already-traced) shapes instead."""
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def measure(
    n: int,
    m: int,
    *,
    deltas: list[int],
    threshold: float = 0.2,
    k: int = 16,
    block: int = 64,
    iters: int = 3,
    seed: int = 0,
) -> dict:
    rng = np.random.default_rng(seed)
    D = rng.normal(size=(n, m)).astype(np.float32)
    out = {
        "n": n, "m": m, "threshold": threshold, "k": k, "block": block,
        "deltas": [],
    }

    def fresh_base():
        return MutableAPSSIndex(D, threshold=threshold, k=k, block_rows=block)

    for delta in deltas:
        new = rng.normal(size=(delta, m)).astype(np.float32)
        full = np.concatenate([D, new])

        # warm every shape on scratch indexes so neither side pays trace
        # time: base-build + append, and the full-size rebuild
        fresh_base().append(new)
        MutableAPSSIndex(full, threshold=threshold, k=k, block_rows=block)

        # time appends against per-iteration fresh bases (append mutates)
        bases = [fresh_base() for _ in range(iters)]
        times = []
        for b in bases:
            t0 = time.perf_counter()
            b.append(new)
            times.append(time.perf_counter() - t0)
        append_s = float(np.median(times))

        rebuild_s = _timed(
            lambda: MutableAPSSIndex(
                full, threshold=threshold, k=k, block_rows=block
            ),
            iters=iters,
        )
        out["deltas"].append({
            "delta": delta,
            "delta_fraction": delta / n,
            "append_s": append_s,
            "rebuild_s": rebuild_s,
            "speedup": rebuild_s / append_s,
        })
    return out


def merge_into(path: str, r: dict) -> None:
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data["mutable"] = r
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
        f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", nargs="?", const="BENCH_apss.json", default=None)
    ap.add_argument("--n", type=int, default=8192)
    ap.add_argument("--m", type=int, default=256)
    ap.add_argument("--block", type=int, default=128)
    ap.add_argument("--threshold", type=float, default=0.2)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale: n=1024, m=128, 2 iters")
    args = ap.parse_args()
    n, m, iters = args.n, args.m, args.iters
    block = args.block
    if args.smoke:
        n, m, iters, block = 1024, 128, 2, 64
    deltas = [max(8, n // 64), n // 16, n // 4]
    r = measure(
        n, m, deltas=deltas, threshold=args.threshold, k=args.k,
        block=block, iters=iters,
    )
    for e in r["deltas"]:
        print(
            f"delta {e['delta']:>5} (n/{round(1/e['delta_fraction'])}): "
            f"append+join {e['append_s']*1e3:8.1f} ms  "
            f"rebuild {e['rebuild_s']*1e3:8.1f} ms  -> "
            f"{e['speedup']:.1f}x"
        )
    if args.json:
        merge_into(args.json, r)
        print(f"-> merged 'mutable' into {args.json}")


if __name__ == "__main__":
    main()
