"""Timing + data helpers shared by the benchmark modules."""

from __future__ import annotations

import time

import jax
import numpy as np


def time_fn(fn, *args, warmup: int = 2, iters: int = 5, return_result: bool = False):
    """Median wall time (µs) of a jitted callable.

    ``return_result=True`` returns ``(us, last_result)`` so callers needing
    the output (e.g. exactness accounting) don't pay an extra untimed call.
    """
    res = None
    for _ in range(warmup):
        res = jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        res = jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    us = float(np.median(times) * 1e6)
    return (us, res) if return_result else us


def bench_corpus(n: int = 1024, m: int = 768, density: float = 0.05, seed: int = 0):
    """Paper-style power-law corpus at CPU-benchmark scale."""
    from repro.data.synthetic import synthetic_corpus

    return synthetic_corpus(n, m, density * m, seed=seed)


def row(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"


def provenance() -> dict:
    """Who/where/when a bench artifact was produced — the join key the
    regression sentinel (``benchmarks.sentinel``) uses to line history
    records up against baselines. Best-effort: fields degrade to
    ``"unknown"`` outside a git checkout or on exotic backends."""
    import datetime
    import subprocess

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True, text=True, timeout=10,
        ).stdout.strip() or "unknown"
    except Exception:
        sha = "unknown"
    try:
        device_kind = jax.devices()[0].device_kind
    except Exception:
        device_kind = "unknown"
    return {
        "git_sha": sha,
        "timestamp": datetime.datetime.now(datetime.timezone.utc).isoformat(),
        "device_kind": device_kind,
        "device_count": len(jax.devices()),
        "jax_version": jax.__version__,
    }
