import os

# 8 virtual devices for the distribution benchmarks (paper Figs 3-6);
# NOT the dry-run's 512 (that runs only via launch/dryrun.py).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Benchmark driver — one module per paper table. Prints
``name,us_per_call,derived`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run [--only sequential,pruning,...]
"""

import argparse  # noqa: E402
import sys  # noqa: E402
import traceback  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: sequential,pruning,blocksize,parallel,roofline")
    args = ap.parse_args()

    from benchmarks import (
        bench_blocksize,
        bench_parallel,
        bench_pruning,
        bench_sequential,
        roofline,
    )

    suites = {
        "sequential": bench_sequential.run,   # paper Tables 2-3
        "pruning": bench_pruning.run,         # paper Tables 5-6
        "blocksize": bench_blocksize.run,     # paper Tables 7-8 / Fig 8
        "parallel": bench_parallel.run,       # paper Figs 3-6
        "roofline": roofline.run,             # EXPERIMENTS.md §Roofline
    }
    selected = args.only.split(",") if args.only else list(suites)

    lines: list = ["name,us_per_call,derived"]
    failed = []
    for name in selected:
        try:
            suites[name](lines)
        except Exception:
            traceback.print_exc()
            failed.append(name)
    print("\n".join(lines))
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
