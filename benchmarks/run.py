import os

# 8 virtual devices for the distribution benchmarks (paper Figs 3-6);
# NOT the dry-run's 512 (that runs only via launch/dryrun.py).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Benchmark driver — one module per paper table. Prints
``name,us_per_call,derived`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run [--only sequential,pruning,...]
    PYTHONPATH=src python -m benchmarks.run --json [PATH] [--n 4096]

``--json`` runs the streaming-extraction comparison (dense-kernel vs fused
vs fused-compacted) at ``--n`` and writes the result to PATH (default
``BENCH_apss.json``) — the perf-trajectory artifact for the fused APSS
path.
"""

import argparse  # noqa: E402
import sys  # noqa: E402
import traceback  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: sequential,pruning,blocksize,parallel,"
                         "apss_stream,roofline")
    ap.add_argument("--json", nargs="?", const="BENCH_apss.json", default=None,
                    metavar="PATH",
                    help="write the streaming APSS comparison to PATH and exit")
    ap.add_argument("--n", type=int, default=4096,
                    help="corpus rows for --json (default 4096)")
    args = ap.parse_args()

    from benchmarks import (
        bench_apss_stream,
        bench_blocksize,
        bench_parallel,
        bench_pruning,
        bench_sequential,
        roofline,
    )

    if args.json:
        r = bench_apss_stream.write_json(args.json, n=args.n)
        for name, v in r["variants"].items():
            print(f"{name}: {v['us_per_call']:.0f} us")
        print(
            f"live tiles {r['live_tiles']}/{r['total_tiles']} "
            f"({r['live_tile_fraction']:.3f}) -> {args.json}"
        )
        return

    suites = {
        "sequential": bench_sequential.run,    # paper Tables 2-3
        "pruning": bench_pruning.run,          # paper Tables 5-6
        "blocksize": bench_blocksize.run,      # paper Tables 7-8 / Fig 8
        "parallel": bench_parallel.run,        # paper Figs 3-6
        "apss_stream": bench_apss_stream.run,  # streaming fused extraction
        "roofline": roofline.run,              # EXPERIMENTS.md §Roofline
    }
    selected = args.only.split(",") if args.only else list(suites)

    lines: list = ["name,us_per_call,derived"]
    failed = []
    for name in selected:
        try:
            suites[name](lines)
        except Exception:
            traceback.print_exc()
            failed.append(name)
    print("\n".join(lines))
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
