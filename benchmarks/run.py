import os

# 8 virtual devices for the distribution benchmarks (paper Figs 3-6);
# NOT the dry-run's 512 (that runs only via launch/dryrun.py).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Benchmark driver — one module per paper table. Prints
``name,us_per_call,derived`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run [--only sequential,pruning,...]
    PYTHONPATH=src python -m benchmarks.run --json [PATH] [--n 4096] \
        [--sweep-n 1024] [--sweep-m 8192]

``--json`` writes the perf-trajectory artifact (default ``BENCH_apss.json``):
the streaming-extraction comparison (dense-kernel vs fused vs
fused-compacted) at ``--n`` plus the sparse density sweep
(``bench_sparse``: dense fused paths vs the inverted-index CSR paths at
densities 0.1%/1%/10%), each entry carrying corpus density and live-tile
fractions so the trajectory stays interpretable across workloads.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import traceback  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: sequential,pruning,blocksize,parallel,"
                         "apss_stream,sparse,roofline")
    ap.add_argument("--json", nargs="?", const="BENCH_apss.json", default=None,
                    metavar="PATH",
                    help="write the APSS perf artifact to PATH and exit")
    ap.add_argument("--n", type=int, default=4096,
                    help="corpus rows for the --json streaming comparison")
    ap.add_argument("--sweep-n", type=int, default=1024,
                    help="corpus rows for the --json sparse density sweep")
    ap.add_argument("--sweep-m", type=int, default=8192,
                    help="corpus dims for the --json sparse density sweep")
    ap.add_argument("--audit", action="store_true",
                    help="with --json: append the model-vs-HLO compile "
                         "audit lane (repro.obs.audit) to the artifact")
    args = ap.parse_args()

    from benchmarks import (
        bench_apss_stream,
        bench_blocksize,
        bench_parallel,
        bench_pruning,
        bench_sequential,
        bench_sparse,
        roofline,
    )

    if args.json:
        def persist(r):
            with open(args.json, "w") as f:
                json.dump(r, f, indent=2)
                f.write("\n")

        from benchmarks.common import provenance

        r = bench_apss_stream.measure(n=args.n)
        r["provenance"] = provenance()
        persist(r)  # minutes of streaming data survive a sweep failure
        for name, v in r["variants"].items():
            print(f"{name}: {v['us_per_call']:.0f} us")
        print(
            f"live tiles {r['live_tiles']}/{r['total_tiles']} "
            f"({r['live_tile_fraction']:.3f})"
        )
        block = min(256, max(64, args.sweep_n // 4))
        r["sparse_sweep"] = bench_sparse.sweep(
            args.sweep_n, args.sweep_m, block=block
        )
        for e in r["sparse_sweep"]["entries"]:
            times = {
                k: f"{v['us_per_call']:.0f}us"
                for k, v in e["variants"].items()
            }
            print(f"density={e['density']:.4f}: {times}")
        if args.audit:
            from repro.obs.audit import run_audit

            report = run_audit()
            r["audit"] = report.as_dict()
            print(report.describe())
        persist(r)
        print(f"-> {args.json}")
        return

    suites = {
        "sequential": bench_sequential.run,    # paper Tables 2-3
        "pruning": bench_pruning.run,          # paper Tables 5-6
        "blocksize": bench_blocksize.run,      # paper Tables 7-8 / Fig 8
        "parallel": bench_parallel.run,        # paper Figs 3-6
        "apss_stream": bench_apss_stream.run,  # streaming fused extraction
        "sparse": bench_sparse.run,            # sparse vs dense density sweep
        "roofline": roofline.run,              # EXPERIMENTS.md §Roofline
    }
    selected = args.only.split(",") if args.only else list(suites)

    lines: list = ["name,us_per_call,derived"]
    failed = []
    for name in selected:
        try:
            suites[name](lines)
        except Exception:
            traceback.print_exc()
            failed.append(name)
    print("\n".join(lines))
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
