"""BENCH_apss.json schema checker — the CI gate as importable code.

Previously an inline heredoc in ``.github/workflows/ci.yml``; now a real
module so the gate is unit-testable (``tests/test_ci_infra.py``), versioned
next to the benchmarks that produce the artifact, and extended alongside
every new benchmark family (latest: provenance + the optional model-vs-HLO
audit lane, plus the ``BENCH_history.jsonl`` record shape the sentinel
appends).

    PYTHONPATH=src python -m benchmarks.check_schema /tmp/bench_smoke.json

Every violation raises :class:`SchemaError` with a path-qualified message;
the acceptance bars baked in here (``chosen_within_2x`` on the single-
device planner lanes, a measured 2-D-sparse entry in the mesh lane) fail
the build on cost-model or variant-matrix drift, not just on missing keys.
The 2-D mesh lane records ``chosen_within_2x`` but is NOT hard-gated: 8
virtual CPU devices share one socket, so collective timings there are
pathological by construction (see ``benchmarks/bench_parallel.py``).
"""

from __future__ import annotations

import json
import sys


class SchemaError(AssertionError):
    """A BENCH artifact violated the schema contract."""


def _require(cond, where: str, msg: str) -> None:
    if not cond:
        raise SchemaError(f"{where}: {msg}")


def _require_keys(d: dict, keys: set, where: str) -> None:
    _require(isinstance(d, dict), where, f"expected an object, got {type(d).__name__}")
    missing = keys - d.keys()
    _require(not missing, where, f"missing keys {sorted(missing)}")


def check_sparse_sweep(doc: dict) -> None:
    _require_keys(
        doc, {"density", "live_tile_fraction", "variants", "sparse_sweep"}, "$"
    )
    sweep = doc["sparse_sweep"]
    _require(sweep.get("entries"), "$.sparse_sweep", "empty sparse sweep")
    for i, e in enumerate(sweep["entries"]):
        where = f"$.sparse_sweep.entries[{i}]"
        _require_keys(
            e,
            {"density", "live_tile_fraction_sparse", "live_tile_fraction_dense",
             "variants", "total_matches"},
            where,
        )
        _require_keys(e["variants"], {"dense-fused", "sparse-xla"}, where + ".variants")


def check_serving(doc: dict) -> None:
    _require_keys(doc, {"serving"}, "$")
    s = doc["serving"]
    _require_keys(
        s,
        {"index_build_us", "index_bytes", "batches", "rebuild",
         "amortized_speedup_batch64", "servers", "early_exit",
         "qps_batch64", "p99_us"},
        "$.serving",
    )
    _require_keys(s["batches"], {"1", "8", "64"}, "$.serving.batches")
    for b, e in s["batches"].items():
        where = f"$.serving.batches[{b}]"
        _require_keys(
            e,
            {"us_per_call", "us_per_query", "qps", "total_matches",
             "latency_us"},
            where,
        )
        # the latency-histogram lane: a per-call distribution, not just a
        # mean — p50 and p99 present, ordered, and positive
        lat = e["latency_us"]
        _require_keys(lat, {"p50", "p99"}, where + ".latency_us")
        _require(lat["p50"] > 0, where + ".latency_us",
                 "p50 must be positive")
        _require(
            lat["p50"] <= lat["p99"], where + ".latency_us",
            f"p50 ({lat['p50']:.0f}us) exceeds p99 ({lat['p99']:.0f}us)",
        )
    _require(s["amortized_speedup_batch64"] > 0, "$.serving",
             "amortized_speedup_batch64 must be positive")
    # The QPS/p99 curve (ISSUE 10): step vs continuous at both batch
    # regimes, each with ordered positive percentiles. The headline claim
    # is gated at the LARGEST regime only — continuous batching must beat
    # the step server's p99 there (at tiny batches the fill-boundary wait
    # the continuous server eliminates is itself tiny, so the step server
    # can legitimately win on thread-overhead grounds).
    _require_keys(s["servers"], {"8", "64"}, "$.serving.servers")
    for regime, servers in s["servers"].items():
        _require_keys(
            servers, {"step", "continuous"}, f"$.serving.servers[{regime}]"
        )
        for name, e in servers.items():
            where = f"$.serving.servers[{regime}].{name}"
            _require_keys(
                e, {"qps", "p50_us", "p95_us", "p99_us", "requests"}, where
            )
            _require(e["qps"] > 0, where, "qps must be positive")
            _require(e["p50_us"] > 0, where, "p50 must be positive")
            _require(
                e["p50_us"] <= e["p95_us"] <= e["p99_us"], where,
                f"percentiles unordered: p50 {e['p50_us']:.0f} / p95 "
                f"{e['p95_us']:.0f} / p99 {e['p99_us']:.0f} us",
            )
    top = s["servers"]["64"]
    _require(
        top["continuous"]["p99_us"] <= top["step"]["p99_us"],
        "$.serving.servers[64]",
        f"continuous p99 ({top['continuous']['p99_us']:.0f}us) exceeds "
        f"step p99 ({top['step']['p99_us']:.0f}us) — slot-granularity "
        "admission should beat the step-boundary latch at full batch",
    )
    # The early-exit lane: the ub-ordered worklist must actually skip
    # live tiles AND stay bit-exact vs the full scan.
    ee = s["early_exit"]
    _require_keys(
        ee, {"n", "m", "threshold", "k", "skipped_tiles", "bit_exact"},
        "$.serving.early_exit",
    )
    _require(ee["skipped_tiles"] > 0, "$.serving.early_exit",
             "early exit skipped no live tiles")
    _require(ee["bit_exact"] is True, "$.serving.early_exit",
             "early exit diverged from the full scan")
    # The sentinel's headline scalars mirror the continuous lane at 64.
    _require(s["qps_batch64"] > 0, "$.serving", "qps_batch64 must be positive")
    _require(s["p99_us"] > 0, "$.serving", "p99_us must be positive")


def _check_planner_corpus(name: str, c: dict, *, where: str, gate_2x: bool) -> None:
    _require_keys(
        c,
        {"summary", "chosen", "chosen_predicted", "entries", "best_measured",
         "chosen_over_best", "chosen_within_2x"},
        where,
    )
    _require(c["entries"], where, "no measured entries")
    for i, e in enumerate(c["entries"]):
        _require_keys(
            e,
            {"config", "predicted_s", "measured_us", "wire_bytes", "flops",
             "compute_s", "comm_s"},
            f"{where}.entries[{i}]",
        )
        _require(e["measured_us"] > 0, f"{where}.entries[{i}]",
                 "measured_us must be positive")
    if gate_2x:
        # the acceptance bar: the chosen plan is within 2x of the best
        # measured variant on every single-device benchmark corpus
        _require(
            c["chosen_within_2x"], where,
            f"chosen plan {c['chosen']} is {c['chosen_over_best']:.2f}x "
            f"the best measured ({c['best_measured']})",
        )


def check_planner(doc: dict) -> None:
    _require_keys(doc, {"planner"}, "$")
    pl = doc["planner"]
    _require_keys(pl, {"profile", "corpora"}, "$.planner")
    _require_keys(
        pl["profile"],
        {"matmul_gflops", "gather_gflops", "score_cost_ns", "device_kind"},
        "$.planner.profile",
    )
    _require_keys(pl["corpora"], {"sparse_lowdens", "dense"}, "$.planner.corpora")
    for name, c in pl["corpora"].items():
        _check_planner_corpus(
            name, c, where=f"$.planner.corpora.{name}", gate_2x=True
        )
    _require(
        pl["corpora"]["sparse_lowdens"]["summary"]["density"] < 0.01,
        "$.planner.corpora.sparse_lowdens", "not in the paper's sparse regime",
    )
    # The composed 2-D lane: planned AND measured on a 2-axis mesh, with
    # the sparse checkerboard family present (the variant matrix's last
    # cell — its absence means the planner gate regressed).
    _require_keys(pl, {"mesh2d"}, "$.planner")
    m2 = pl["mesh2d"]
    _require_keys(m2, {"mesh", "corpora"}, "$.planner.mesh2d")
    _require(len(m2["mesh"]) == 2, "$.planner.mesh2d.mesh", "expected 2 axes")
    _require(m2["corpora"], "$.planner.mesh2d.corpora", "no corpora")
    for name, c in m2["corpora"].items():
        where = f"$.planner.mesh2d.corpora.{name}"
        _check_planner_corpus(name, c, where=where, gate_2x=False)
        configs = [e["config"] for e in c["entries"]]
        _require(
            any(cfg.startswith("2d/") and "sparse" in cfg for cfg in configs),
            where, f"no measured 2d-sparse entry among {configs}",
        )
        _require(
            any(cfg.startswith("2d/") and "dense" in cfg for cfg in configs),
            where, f"no measured 2d-dense entry among {configs}",
        )


def check_mutable(doc: dict) -> None:
    """The live-corpus lane (ISSUE 7): per-append cost must scale with the
    delta, not the corpus — gated as append+delta-join ≥ 5× faster than a
    full rebuild at some delta ≤ 1/16 of the corpus, with every measured
    delta beating rebuild outright."""
    _require_keys(doc, {"mutable"}, "$")
    mu = doc["mutable"]
    _require_keys(
        mu, {"n", "m", "threshold", "k", "block", "deltas"}, "$.mutable"
    )
    _require(mu["deltas"], "$.mutable.deltas", "no measured deltas")
    small = []
    for i, e in enumerate(mu["deltas"]):
        where = f"$.mutable.deltas[{i}]"
        _require_keys(
            e,
            {"delta", "delta_fraction", "append_s", "rebuild_s", "speedup"},
            where,
        )
        _require(e["append_s"] > 0 and e["rebuild_s"] > 0, where,
                 "timings must be positive")
        if e["delta_fraction"] <= 1 / 16:
            small.append(e)
    _require(small, "$.mutable.deltas", "no delta <= n/16 measured")
    best = max(e["speedup"] for e in small)
    _require(
        best >= 5.0,
        "$.mutable.deltas",
        f"append+delta-join only {best:.1f}x faster than rebuild at "
        "delta <= n/16 (acceptance bar: >= 5x)",
    )


def check_provenance(doc: dict) -> None:
    """The sentinel's join key: every artifact must say who produced it."""
    _require_keys(doc, {"provenance"}, "$")
    p = doc["provenance"]
    _require_keys(
        p,
        {"git_sha", "timestamp", "device_kind", "jax_version"},
        "$.provenance",
    )
    for key in ("git_sha", "timestamp", "device_kind", "jax_version"):
        _require(
            isinstance(p[key], str) and p[key], f"$.provenance.{key}",
            "must be a non-empty string",
        )


def check_audit(doc: dict) -> None:
    """The model-vs-HLO audit lane (optional — present when the artifact
    was produced with ``--audit``): every entry carries both sides of
    each ratio plus its compile record, and the dense FLOP gate holds."""
    if "audit" not in doc:
        return
    a = doc["audit"]
    _require_keys(a, {"entries", "gated_ok", "gated_families"}, "$.audit")
    _require(a["entries"], "$.audit.entries", "empty audit")
    for i, e in enumerate(a["entries"]):
        where = f"$.audit.entries[{i}]"
        _require_keys(
            e,
            {"family", "predicted_flops", "hlo_flops", "flop_ratio",
             "predicted_link_bytes", "hlo_link_bytes",
             "predicted_hbm_bytes", "hlo_hbm_bytes", "compile"},
            where,
        )
        _require_keys(
            e["compile"], {"t_compile_s", "total_bytes"}, where + ".compile"
        )
    families = {e["family"] for e in a["entries"]}
    missing = set(a["gated_families"]) - families
    _require(not missing, "$.audit", f"gated families missing: {sorted(missing)}")
    _require(
        a["gated_ok"], "$.audit",
        "dense FLOP ratio gate failed (model vs HLO drift)",
    )


def check_history_record(rec: dict) -> None:
    """One BENCH_history.jsonl line (``benchmarks.sentinel`` record)."""
    _require_keys(
        rec, {"git_sha", "timestamp", "device_kind", "jax_version", "metrics"},
        "$history",
    )
    _require(isinstance(rec["metrics"], dict), "$history.metrics",
             "must be an object")
    _require(rec["metrics"], "$history.metrics", "empty metric dict")
    for name, v in rec["metrics"].items():
        _require(
            isinstance(v, (int, float)) and v >= 0,
            f"$history.metrics[{name}]", "must be a non-negative number",
        )


def check(doc: dict) -> None:
    """Validate one BENCH artifact; raises :class:`SchemaError` on the first
    violation."""
    check_sparse_sweep(doc)
    check_serving(doc)
    check_planner(doc)
    check_mutable(doc)
    check_provenance(doc)
    check_audit(doc)


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    path = argv[0] if argv else "BENCH_apss.json"
    with open(path) as f:
        doc = json.load(f)
    try:
        check(doc)
    except SchemaError as e:
        print(f"BENCH schema FAIL ({path}): {e}", file=sys.stderr)
        return 1
    print(
        f"BENCH schema OK ({path}): sweep + serving + planner "
        "(incl. 2-D lane) + mutable + provenance"
        + (" + audit" if "audit" in doc else "")
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
