"""Streaming fused extraction vs the seed dense-kernel path.

Three variants of the kernel-backed self-join, on a topic-clustered Zipfian
corpus (the block-pruning-friendly regime — see ``data.synthetic``):

  dense-kernel     seed path: Pallas thresholded n×n score matrix in HBM,
                   then XLA ``extract_matches`` over the dense result
  fused            streaming kernel: matmul → threshold → top-k merge →
                   count fused, O(n·k) output, pruned tiles masked with
                   ``@pl.when`` (still burn a pipeline slot)
  fused-compacted  fused + live-tile worklist via scalar prefetch: pruned
                   tiles cost zero grid steps, upper-triangular tiles only
                   (S = Sᵀ)

``run`` emits the usual CSV lines at a CPU-friendly n; ``measure`` runs
the same comparison at production-proof scale (n ≥ 4096) for the
``BENCH_apss.json`` artifact (written by ``run.py --json``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core.matches import extract_matches
from repro.core.pruning import block_prune_mask, prune_stats
from repro.kernels.apss_block.ops import (
    apss_block_matmul,
    apss_fused,
    apss_fused_compacted,
)

K = 32
BM = 256


def _corpus(n: int, m: int = 768):
    from repro.data.synthetic import clustered_corpus

    return jnp.asarray(clustered_corpus(n, m, 8, n_clusters=32, seed=0))


def _variants(threshold: float):
    """name → jit-ready callable D → Matches (or dense scores for seed)."""
    dense = jax.jit(
        lambda d: extract_matches(
            apss_block_matmul(
                d, d, threshold, block_m=BM, block_n=BM, block_k=256
            ),
            threshold, K,
        )
    )
    fused = jax.jit(
        lambda d: apss_fused(
            d, d, threshold, K, block_m=BM, block_n=BM, block_k=256
        )
    )

    def compacted(d):
        # Host-side worklist compaction: not jittable end-to-end, timed as
        # called in production (mask + compaction on every call).
        return apss_fused_compacted(d, threshold, K, block_m=BM, block_k=256)

    return {"dense-kernel": dense, "fused": fused, "fused-compacted": compacted}


def _measure(n: int, threshold: float, *, warmup: int, iters: int):
    import numpy as np

    D = _corpus(n)
    mask = block_prune_mask(D, D, threshold, BM, BM, use_minsize=False)
    stats = prune_stats(mask)
    out = {
        "n": n,
        "m": int(D.shape[1]),
        "k": K,
        "threshold": threshold,
        "block": BM,
        "density": float(np.count_nonzero(np.asarray(D))) / D.size,
        "live_tile_fraction": float(stats.live_fraction),
        "live_tiles": int(stats.live_blocks),
        "total_tiles": int(stats.total_blocks),
        "variants": {},
    }
    counts = {}
    for name, fn in _variants(threshold).items():
        us, res = time_fn(fn, D, warmup=warmup, iters=iters, return_result=True)
        counts[name] = int(res.counts.sum()) if hasattr(res, "counts") else None
        out["variants"][name] = {"us_per_call": us}
    # All variants must agree on the exact directed match count.
    assert len({c for c in counts.values() if c is not None}) == 1, counts
    out["total_matches"] = counts["fused"]
    return out


def run(lines: list) -> None:
    r = _measure(1024, 0.4, warmup=1, iters=3)
    for name, v in r["variants"].items():
        lines.append(row(
            f"apss_stream/{name}-n1024", v["us_per_call"],
            f"live_tiles={r['live_tile_fraction']:.3f};matches={r['total_matches']}",
        ))


def measure(n: int = 4096, threshold: float = 0.4) -> dict:
    """The streaming comparison dict. No file I/O here: ``run.py --json``
    is the single writer of BENCH_apss.json (this + the sparse density
    sweep), so the artifact schema cannot drift between writers."""
    return _measure(n, threshold, warmup=1, iters=2)
