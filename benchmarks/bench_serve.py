"""Serving amortization: build-once APSSIndex vs rebuild-per-call.

The serving subsystem's whole thesis (DESIGN.md §6): corpus-side support
structures — normalized CSR, block maxweight vectors, posting-list
supports, ``bdims``/``bx`` compaction — are query-invariant, so a server
should pay for them ONCE. This bench quantifies the claim on the paper's
regime (sparse clustered-Zipfian corpus, default n=65536 m=8192):

- ``index_build_us``     one-time cost of ``build_index``
- ``batches[B]``         per-query latency + QPS at batch 1/8/64 against
                         the prebuilt index (one ``query_topk`` per batch),
                         plus a per-call latency distribution
                         (``latency_us``: p50/p95/p99 off an
                         ``obs.metrics.Histogram`` — the serving
                         latency-histogram lane checked by the CI schema)
- ``rebuild``            the status-quo baseline: every batch-64 call
                         rebuilds the index from the raw corpus first
- ``amortized_speedup_batch64``  rebuild ÷ indexed per-query latency —
                         the headline amortization factor (≥ 5× required)

Two throughput lanes ride along (ISSUE 10 — the CI-gated QPS/p99 curve):

- ``servers``      step-boundary :class:`RetrievalServer` vs slot-admission
                   :class:`ContinuousRetrievalServer` at two batch regimes,
                   each measured closed-loop (burst → ``qps``) AND under
                   paced arrivals (→ per-request submit→latch p50/p95/p99).
                   The step server quantizes every request to its batch's
                   fill boundary; continuous batching starts service at
                   submit — the p99 gap between the two IS the tentpole's
                   claim, and ``check_schema`` requires continuous ≤ step.
- ``early_exit``   the ub-ordered worklist's early-exit on an overlapping
                   clustered corpus: skipped live tiles (> 0 required) with
                   bit-exact top-k vs the full scan.

``serving.qps_batch64`` / ``serving.p99_us`` scalars from the continuous
lane feed ``benchmarks.sentinel`` (QPS is gated higher-is-better).

Queries are perturbed corpus rows drawn from a contiguous cluster range
per batch (topical traffic — the regime where the prebuilt posting lists
prune hardest). Run standalone to merge a ``serving`` section into
BENCH_apss.json (``--smoke`` for the CI-sized run):

    PYTHONPATH=src python -m benchmarks.bench_serve --json BENCH_apss.json
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

BATCHES = (1, 8, 64)
SERVER_REGIMES = (8, 64)


def _drive_server(srv, queries, *, nreq: int, gap_s: float, step_when_full: bool):
    """Push ``nreq`` requests through a server; return (qps, latency hist).

    ``gap_s > 0`` paces arrivals (open-loop-ish traffic: per-request
    latency includes queueing); ``gap_s == 0`` is a closed-loop burst
    (throughput capacity). The step server is driven the way its contract
    reads — ``step()`` at each batch-full boundary, drain at the end — so
    its latencies honestly include the fill wait the continuous server
    eliminates.
    """
    import time as _time

    from repro.obs.metrics import MetricsRegistry

    with MetricsRegistry() as reg:
        t0 = _time.perf_counter()
        rids = []
        for i in range(nreq):
            rids.append(srv.submit(queries[i % len(queries)]))
            if step_when_full and len(srv._pending) >= srv.max_batch:
                srv.step()
            if gap_s:
                _time.sleep(gap_s)
        for r in rids:
            srv.result(r)
        wall = _time.perf_counter() - t0
        hist = reg.histograms.get("serving.latency_s")
    return nreq / wall, hist


def measure_servers(
    index,
    queries,
    *,
    threshold: float,
    k: int,
    nreq: int = 192,
    workers: int = 2,
) -> dict:
    """Step vs continuous server, closed-loop QPS + paced-arrival tail."""
    from repro.serving import ContinuousRetrievalServer, RetrievalServer

    out: dict = {}
    for max_batch in SERVER_REGIMES:
        kwargs = dict(
            threshold=threshold, k=k, max_batch=max_batch, cache_size=0
        )

        def make(name):
            if name == "continuous":
                return ContinuousRetrievalServer(
                    index, workers=workers, **kwargs
                )
            return RetrievalServer(index, **kwargs)

        # Warm the block_q bucket's compile cache off the clock.
        warm = make("step")
        warm.serve(queries[:max_batch])
        warm.close()
        # Arrival pacing at ~the full-batch service rate: requests arrive
        # about as fast as a full batch retires them, so the step server's
        # fill-boundary wait is visible but neither server falls behind.
        burst_qps, _ = _drive_server(
            make("step"), queries, nreq=nreq, gap_s=0.0, step_when_full=True
        )
        gap_s = 1.0 / max(burst_qps, 1.0)
        regime: dict = {}
        for name in ("step", "continuous"):
            srv = make(name)
            try:
                qps, _ = _drive_server(
                    srv, queries, nreq=nreq, gap_s=0.0,
                    step_when_full=(name == "step"),
                )
                _, hist = _drive_server(
                    srv, queries, nreq=nreq, gap_s=gap_s,
                    step_when_full=(name == "step"),
                )
            finally:
                srv.close()
            regime[name] = {
                "qps": qps,
                "p50_us": hist.quantile(0.50) * 1e6,
                "p95_us": hist.quantile(0.95) * 1e6,
                "p99_us": hist.quantile(0.99) * 1e6,
                "requests": nreq,
                "paced_gap_us": gap_s * 1e6,
            }
        out[str(max_batch)] = regime
    return out


def measure_early_exit(
    *,
    n: int = 8192,
    m: int = 2048,
    avg_nnz: float = 16.0,
    block: int = 64,
    k: int = 8,
    threshold: float = 0.01,
    batch: int = 64,
    seed: int = 2,
) -> dict:
    """Early-exit lane: skipped tiles > 0 with bit-exact results.

    Clustered corpus WITH a weak shared vocabulary (``overlap_dims``): at a
    low threshold, cross-cluster tiles stay live (small nonzero bound — the
    mask cannot drop them) but lose to any query whose top-k fills within
    its own cluster, so the ub-descending scan stops before scoring them.
    """
    import numpy as np

    from repro.data.sparse import perturbed_queries, sparse_clustered_corpus
    from repro.obs.metrics import MetricsRegistry
    from repro.serving import build_index, query_topk

    sp = sparse_clustered_corpus(
        n, m, avg_nnz, n_clusters=16, seed=seed, overlap_dims=8
    )
    index = build_index(sp, block_rows=block, normalize=False)
    Q = perturbed_queries(sp, batch, seed=seed + 1)
    with MetricsRegistry() as reg:
        ref = query_topk(index, Q, threshold, k)
        ee = query_topk(index, Q, threshold, k, early_exit=True)
    skipped = int(reg.counters.get("serving.early_exit_skipped_tiles", 0))
    bit_exact = bool(
        np.array_equal(np.asarray(ref.values), np.asarray(ee.values))
        and np.array_equal(np.asarray(ref.indices), np.asarray(ee.indices))
        and np.array_equal(
            np.minimum(np.asarray(ref.counts), k), np.asarray(ee.counts)
        )
    )
    return {
        "n": sp.n,
        "m": sp.m,
        "threshold": threshold,
        "k": k,
        "skipped_tiles": skipped,
        "bit_exact": bit_exact,
    }


def measure(
    n: int = 65536,
    m: int = 8192,
    *,
    avg_nnz: float = 16.0,
    block: int = 256,
    threshold: float = 0.5,
    k: int = 32,
    iters: int = 3,
    latency_iters: int = 20,
    server_requests: int = 192,
    ee_n: int = 8192,
    ee_m: int = 2048,
    seed: int = 0,
) -> dict:
    import jax

    from benchmarks.common import time_fn
    from repro.data.sparse import perturbed_queries, sparse_clustered_corpus
    from repro.obs.metrics import Histogram
    from repro.serving import build_index, query_topk
    from repro.serving.index import index_nbytes

    t0 = time.perf_counter()
    sp = sparse_clustered_corpus(n, m, avg_nnz, n_clusters=32, seed=seed)
    gen_s = time.perf_counter() - t0

    def build():
        return build_index(sp, block_rows=block, normalize=False)

    t0 = time.perf_counter()
    index = build()
    jax.block_until_ready(jax.tree_util.tree_leaves(index))
    build_us = (time.perf_counter() - t0) * 1e6

    out = {
        "n": sp.n,
        "m": sp.m,
        "avg_nnz": avg_nnz,
        "block_rows": block,
        "threshold": threshold,
        "k": k,
        "corpus_gen_s": round(gen_s, 2),
        "index_build_us": build_us,
        "index_bytes": index_nbytes(index),
        "batches": {},
    }

    qmax = perturbed_queries(sp, max(BATCHES), seed=seed + 1)
    for B in BATCHES:
        Q = qmax[:B]
        us, res = time_fn(
            lambda q: query_topk(index, q, threshold, k),
            Q, warmup=1, iters=iters, return_result=True,
        )
        # Per-call latency distribution: individually timed warm calls into
        # an exponential-bucket histogram — the tail (p99) is what a serving
        # deadline budget actually has to cover, and a mean can't show it.
        hist = Histogram()
        for _ in range(max(latency_iters, iters)):
            t0 = time.perf_counter()
            jax.block_until_ready(query_topk(index, Q, threshold, k))
            hist.observe(time.perf_counter() - t0)
        out["batches"][str(B)] = {
            "us_per_call": us,
            "us_per_query": us / B,
            "qps": 1e6 * B / us,
            "total_matches": int(np.asarray(res.counts).sum()),
            "latency_us": {
                "p50": hist.quantile(0.50) * 1e6,
                "p95": hist.quantile(0.95) * 1e6,
                "p99": hist.quantile(0.99) * 1e6,
                "samples": hist.count,
            },
        }

    # Status-quo baseline: rebuild every corpus-side structure per call
    # (what a similarity_topk-shaped entry point does today), batch 64.
    B = max(BATCHES)
    Q = qmax[:B]

    def rebuild_and_query(q):
        return query_topk(build(), q, threshold, k)

    rb_us = time_fn(rebuild_and_query, Q, warmup=1, iters=iters)
    indexed_pq = out["batches"][str(B)]["us_per_query"]
    out["rebuild"] = {
        "us_per_call": rb_us,
        "us_per_query": rb_us / B,
    }
    out["amortized_speedup_batch64"] = (rb_us / B) / indexed_pq

    # Server throughput lanes (ISSUE 10): the QPS/p99 curve + early-exit.
    out["servers"] = measure_servers(
        index, qmax, threshold=threshold, k=k,
        nreq=server_requests, workers=2,
    )
    out["early_exit"] = measure_early_exit(
        n=ee_n, m=ee_m, avg_nnz=avg_nnz, seed=seed + 2,
    )
    cont64 = out["servers"]["64"]["continuous"]
    out["qps_batch64"] = cont64["qps"]
    out["p99_us"] = cont64["p99_us"]
    return out


def merge_into(path: str, r: dict) -> None:
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data["serving"] = r
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
        f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", nargs="?", const="BENCH_apss.json", default=None)
    ap.add_argument("--n", type=int, default=65536)
    ap.add_argument("--m", type=int, default=8192)
    ap.add_argument("--avg-nnz", type=float, default=16.0)
    ap.add_argument("--block", type=int, default=256)
    ap.add_argument("--threshold", type=float, default=0.5)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: small corpus, fewer iters/requests")
    args = ap.parse_args()

    if args.smoke:
        args.n = min(args.n, 4096)
        args.m = min(args.m, 1024)
        args.iters = min(args.iters, 2)
        kwargs = dict(server_requests=96, ee_n=2048, ee_m=1024)
    else:
        kwargs = {}
    r = measure(
        args.n, args.m, avg_nnz=args.avg_nnz, block=args.block,
        threshold=args.threshold, k=args.k, iters=args.iters, **kwargs,
    )
    print(f"index build: {r['index_build_us']/1e6:.2f}s "
          f"({r['index_bytes']/2**20:.0f} MiB)")
    for B, e in r["batches"].items():
        lat = e["latency_us"]
        print(f"batch {B:>3}: {e['us_per_query']:.0f} us/query "
              f"({e['qps']:.1f} QPS, {e['total_matches']} matches) "
              f"per-call p50/p95/p99 {lat['p50']:.0f}/{lat['p95']:.0f}/"
              f"{lat['p99']:.0f} us ({lat['samples']} samples)")
    print(f"rebuild-per-call batch 64: {r['rebuild']['us_per_query']:.0f} "
          f"us/query -> amortized speedup "
          f"{r['amortized_speedup_batch64']:.1f}x")
    for regime, servers in r["servers"].items():
        for name, e in servers.items():
            print(f"server max_batch={regime:>2} {name:>10}: "
                  f"{e['qps']:.1f} QPS burst, paced p50/p95/p99 "
                  f"{e['p50_us']:.0f}/{e['p95_us']:.0f}/{e['p99_us']:.0f} us")
    ee = r["early_exit"]
    print(f"early-exit (n={ee['n']} t={ee['threshold']}): "
          f"{ee['skipped_tiles']} tiles skipped, bit_exact={ee['bit_exact']}")
    print(f"headline: serving.qps_batch64={r['qps_batch64']:.1f} "
          f"serving.p99_us={r['p99_us']:.0f}")
    if args.json:
        merge_into(args.json, r)
        print(f"-> merged 'serving' into {args.json}")


if __name__ == "__main__":
    main()
