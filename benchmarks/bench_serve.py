"""Serving amortization: build-once APSSIndex vs rebuild-per-call.

The serving subsystem's whole thesis (DESIGN.md §6): corpus-side support
structures — normalized CSR, block maxweight vectors, posting-list
supports, ``bdims``/``bx`` compaction — are query-invariant, so a server
should pay for them ONCE. This bench quantifies the claim on the paper's
regime (sparse clustered-Zipfian corpus, default n=65536 m=8192):

- ``index_build_us``     one-time cost of ``build_index``
- ``batches[B]``         per-query latency + QPS at batch 1/8/64 against
                         the prebuilt index (one ``query_topk`` per batch),
                         plus a per-call latency distribution
                         (``latency_us``: p50/p95/p99 off an
                         ``obs.metrics.Histogram`` — the serving
                         latency-histogram lane checked by the CI schema)
- ``rebuild``            the status-quo baseline: every batch-64 call
                         rebuilds the index from the raw corpus first
- ``amortized_speedup_batch64``  rebuild ÷ indexed per-query latency —
                         the headline amortization factor (≥ 5× required)

Queries are perturbed corpus rows drawn from a contiguous cluster range
per batch (topical traffic — the regime where the prebuilt posting lists
prune hardest). Run standalone to merge a ``serving`` section into
BENCH_apss.json:

    PYTHONPATH=src python -m benchmarks.bench_serve --json BENCH_apss.json
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

BATCHES = (1, 8, 64)


def measure(
    n: int = 65536,
    m: int = 8192,
    *,
    avg_nnz: float = 16.0,
    block: int = 256,
    threshold: float = 0.5,
    k: int = 32,
    iters: int = 3,
    latency_iters: int = 20,
    seed: int = 0,
) -> dict:
    import jax

    from benchmarks.common import time_fn
    from repro.data.sparse import perturbed_queries, sparse_clustered_corpus
    from repro.obs.metrics import Histogram
    from repro.serving import build_index, query_topk
    from repro.serving.index import index_nbytes

    t0 = time.perf_counter()
    sp = sparse_clustered_corpus(n, m, avg_nnz, n_clusters=32, seed=seed)
    gen_s = time.perf_counter() - t0

    def build():
        return build_index(sp, block_rows=block, normalize=False)

    t0 = time.perf_counter()
    index = build()
    jax.block_until_ready(jax.tree_util.tree_leaves(index))
    build_us = (time.perf_counter() - t0) * 1e6

    out = {
        "n": sp.n,
        "m": sp.m,
        "avg_nnz": avg_nnz,
        "block_rows": block,
        "threshold": threshold,
        "k": k,
        "corpus_gen_s": round(gen_s, 2),
        "index_build_us": build_us,
        "index_bytes": index_nbytes(index),
        "batches": {},
    }

    qmax = perturbed_queries(sp, max(BATCHES), seed=seed + 1)
    for B in BATCHES:
        Q = qmax[:B]
        us, res = time_fn(
            lambda q: query_topk(index, q, threshold, k),
            Q, warmup=1, iters=iters, return_result=True,
        )
        # Per-call latency distribution: individually timed warm calls into
        # an exponential-bucket histogram — the tail (p99) is what a serving
        # deadline budget actually has to cover, and a mean can't show it.
        hist = Histogram()
        for _ in range(max(latency_iters, iters)):
            t0 = time.perf_counter()
            jax.block_until_ready(query_topk(index, Q, threshold, k))
            hist.observe(time.perf_counter() - t0)
        out["batches"][str(B)] = {
            "us_per_call": us,
            "us_per_query": us / B,
            "qps": 1e6 * B / us,
            "total_matches": int(np.asarray(res.counts).sum()),
            "latency_us": {
                "p50": hist.quantile(0.50) * 1e6,
                "p95": hist.quantile(0.95) * 1e6,
                "p99": hist.quantile(0.99) * 1e6,
                "samples": hist.count,
            },
        }

    # Status-quo baseline: rebuild every corpus-side structure per call
    # (what a similarity_topk-shaped entry point does today), batch 64.
    B = max(BATCHES)
    Q = qmax[:B]

    def rebuild_and_query(q):
        return query_topk(build(), q, threshold, k)

    rb_us = time_fn(rebuild_and_query, Q, warmup=1, iters=iters)
    indexed_pq = out["batches"][str(B)]["us_per_query"]
    out["rebuild"] = {
        "us_per_call": rb_us,
        "us_per_query": rb_us / B,
    }
    out["amortized_speedup_batch64"] = (rb_us / B) / indexed_pq
    return out


def merge_into(path: str, r: dict) -> None:
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data["serving"] = r
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
        f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", nargs="?", const="BENCH_apss.json", default=None)
    ap.add_argument("--n", type=int, default=65536)
    ap.add_argument("--m", type=int, default=8192)
    ap.add_argument("--avg-nnz", type=float, default=16.0)
    ap.add_argument("--block", type=int, default=256)
    ap.add_argument("--threshold", type=float, default=0.5)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args()

    r = measure(
        args.n, args.m, avg_nnz=args.avg_nnz, block=args.block,
        threshold=args.threshold, k=args.k, iters=args.iters,
    )
    print(f"index build: {r['index_build_us']/1e6:.2f}s "
          f"({r['index_bytes']/2**20:.0f} MiB)")
    for B, e in r["batches"].items():
        lat = e["latency_us"]
        print(f"batch {B:>3}: {e['us_per_query']:.0f} us/query "
              f"({e['qps']:.1f} QPS, {e['total_matches']} matches) "
              f"per-call p50/p95/p99 {lat['p50']:.0f}/{lat['p95']:.0f}/"
              f"{lat['p99']:.0f} us ({lat['samples']} samples)")
    print(f"rebuild-per-call batch 64: {r['rebuild']['us_per_query']:.0f} "
          f"us/query -> amortized speedup "
          f"{r['amortized_speedup_batch64']:.1f}x")
    if args.json:
        merge_into(args.json, r)
        print(f"-> merged 'serving' into {args.json}")


if __name__ == "__main__":
    main()
