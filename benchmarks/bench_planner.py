import os

# 8 virtual devices so the distributed candidates are real (NOT the
# dry-run's 512); must precede the first jax import.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Planner benchmark: predicted vs measured cost per variant.

The planner's performance story must be falsifiable: for each benchmark
corpus this module (1) calibrates the hardware profile, (2) plans with the
full candidate set, (3) MEASURES one representative configuration per
variant family (the best-predicted block size of each), and (4) records
predicted-vs-measured side by side into ``BENCH_apss.json`` under
``"planner"`` — including whether the chosen plan landed within 2× of the
best measured variant (asserted by the CI schema check). A drift in the
cost model now shows up as a benchmark regression, not folklore.

    PYTHONPATH=src python -m benchmarks.bench_planner --json [PATH] [--smoke]
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402


def _corpora(*, smoke: bool):
    """Benchmark corpora: the paper's sparse regime + a dense corpus."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core.apss import normalize_rows
    from repro.data.sparse import sparse_zipfian_corpus

    # The sparse lane sits in the paper's Table-1 regime (density ≈ 0.1%,
    # m ≫ cap) where the CSR path wins on any hardware; the dense lane is a
    # fully dense corpus where the sparse representation isn't even a
    # candidate — between them the planner must flip representation.
    if smoke:
        sparse_shape, dense_shape = (1024, 8192, 8.0), (512, 256)
    else:
        sparse_shape, dense_shape = (2048, 8192, 16.0), (1024, 512)
    rng = np.random.default_rng(7)
    dense = np.abs(rng.standard_normal(dense_shape)).astype(np.float32)
    return {
        "sparse_lowdens": sparse_zipfian_corpus(*sparse_shape, seed=0),
        "dense": np.asarray(normalize_rows(jnp.asarray(dense))),
    }


def _measure_families(plan, corpus, threshold, k, mesh, iters, max_families):
    """Measure one config per variant family (its best-predicted block size)
    and grade the emulated plan+autotune choice (best of the top-3 measured
    families) against the best of EVERY measured family.

    Block-size ties within a family are modeled identically, so measuring
    all of them would only add noise to the within-2× comparison. The
    corpus is converted once per representation (``prepared=True``) so
    timings cover the join the model prices, not per-call ``to_dense``.
    """
    from benchmarks.common import time_fn
    from repro.planner.plan import _to_representation, execute

    seen: set = set()
    rep_cache: dict = {}
    entries = []
    for e in plan.estimates:
        fam = (e.config.kind, e.config.schedule,
               e.config.accumulation, e.config.sparse)
        if fam in seen or len(entries) >= max_families:
            continue
        seen.add(fam)
        if e.config.sparse not in rep_cache:
            rep_cache[e.config.sparse] = _to_representation(
                corpus, e.config.sparse
            )
        data = rep_cache[e.config.sparse]
        us = time_fn(
            lambda cfg=e.config, d=data: execute(
                cfg, d, threshold, k, mesh, prepared=True
            ),
            warmup=1, iters=iters,
        )
        e.measured_s = us * 1e-6
        entries.append({**e.as_dict(), "measured_us": us})
    best = min(entries, key=lambda d: d["measured_us"])
    # The planner's full operating mode is plan + autotune: the best-
    # predicted config of each of the top-3 distinct variant families is
    # microbenchmarked and the measured winner runs — exactly what
    # plan_apss(autotune=True) does. Entries are family-deduped in
    # predicted order, so the autotuned choice is the best of the first
    # three — graded against the best of EVERY measured family.
    chosen = min(entries[:3], key=lambda d: d["measured_us"])
    ratio = chosen["measured_us"] / best["measured_us"]
    return {
        "summary": plan.summary.as_dict(),
        "chosen_predicted": plan.config.name,
        "chosen": chosen["config"],
        "autotuned": True,
        "entries": entries,
        "best_measured": best["config"],
        "chosen_over_best": ratio,
        "chosen_within_2x": ratio <= 2.0,
    }


def measure(
    *,
    smoke: bool = False,
    threshold: float = 0.5,
    k: int = 32,
    iters: int = 3,
    use_mesh: bool | None = None,
    max_families: int = 8,
) -> dict:
    import jax

    from repro.compat import make_mesh
    from repro.planner.calibrate import calibrate
    from repro.planner.plan import plan_apss

    # One-shot hardware calibration (cached to JSON keyed by device kind);
    # on virtual-device hosts this prices the "parallel" variants honestly.
    profile = calibrate(save=True)
    if use_mesh is None:
        use_mesh = not smoke
    mesh = (
        make_mesh((jax.device_count(),), ("data",))
        if use_mesh and jax.device_count() > 1
        else None
    )

    out = {
        "profile": dataclasses.asdict(profile),
        "threshold": threshold,
        "k": k,
        "mesh_devices": 1 if mesh is None else jax.device_count(),
        "corpora": {},
    }
    measured_estimates = []
    corpora = _corpora(smoke=smoke)
    for name, corpus in corpora.items():
        plan = plan_apss(
            corpus, threshold, k, mesh, profile=profile, include_kernel=False
        )
        rec = _measure_families(
            plan, corpus, threshold, k, mesh, iters, max_families
        )
        measured_estimates.extend(plan.estimates)
        out["corpora"][name] = rec
        _print_corpus(name, rec)

    # 2-D lane: the composed checkerboard families (dense AND sparse — the
    # full representation × distribution matrix) planned and measured on a
    # 2-axis mesh. Always runs when 8 devices exist (the CI matrix forces 8
    # virtual devices job-wide), including --smoke.
    if jax.device_count() >= 8:
        mesh2 = make_mesh((4, 2), ("data", "model"))
        sp = corpora["sparse_lowdens"]
        plan2 = plan_apss(
            sp, threshold, k, mesh2, profile=profile, include_kernel=False
        )
        rec = _measure_families(
            plan2, sp, threshold, k, mesh2, iters, max_families
        )
        out["mesh2d"] = {
            "mesh": {str(a): int(v) for a, v in mesh2.shape.items()},
            "corpora": {"sparse_lowdens": rec},
        }
        measured_estimates.extend(plan2.estimates)
        _print_corpus("sparse_lowdens @ (4,2)", rec)

    # Drift lane: every measured family above is a predicted-vs-measured
    # pair — fold them into a DriftReport so a rotten CalibrationProfile is
    # flagged by the bench itself, not discovered via a within-2x MISS.
    from repro.obs import drift

    report = drift.drift_report(
        drift.residuals_from_estimates(measured_estimates), profile=profile
    )
    out["drift"] = report.as_dict()
    print(report.describe())
    return out


def _print_corpus(name: str, rec: dict) -> None:
    print(
        f"[planner] {name}: chosen {rec['chosen']} "
        f"(predicted-best {rec['chosen_predicted']}), "
        f"best measured {rec['best_measured']}, "
        f"ratio {rec['chosen_over_best']:.2f}x"
    )
    for d in rec["entries"]:
        print(
            f"    {d['config']:<44} predicted {d['predicted_s']*1e6:>9.0f}us"
            f"  measured {d['measured_us']:>9.0f}us"
            f"  wire {d['wire_bytes']/1e6:>7.2f}MB"
        )


def merge_into(path: str, r: dict) -> None:
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data["planner"] = r
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
        f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", nargs="?", const="BENCH_apss.json", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="small corpora, single-device candidates (CI)")
    ap.add_argument("--threshold", type=float, default=0.5)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write a Chrome/Perfetto trace-event JSON of the"
                         " plan/measure runs (nested plan -> execute ->"
                         " ring_step spans) to PATH")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a metrics snapshot to PATH (.prom/.txt ->"
                         " Prometheus text, otherwise JSON)")
    args = ap.parse_args()

    import contextlib

    from repro.obs import MetricsRegistry, Tracer, export

    tracer = Tracer() if args.trace_out else None
    registry = MetricsRegistry() if args.metrics_out else None
    with contextlib.ExitStack() as stack:
        if registry is not None:
            stack.enter_context(registry)
        if tracer is not None:
            stack.enter_context(tracer)
        r = measure(
            smoke=args.smoke, threshold=args.threshold, k=args.k,
            iters=2 if args.smoke else args.iters,
        )
    if tracer is not None:
        export.write_chrome_trace(args.trace_out, tracer, registry)
        print(f"[obs] trace -> {args.trace_out}")
    if registry is not None:
        export.write_metrics(args.metrics_out, registry)
        print(f"[obs] metrics -> {args.metrics_out}")
    for name, c in r["corpora"].items():
        ok = "OK" if c["chosen_within_2x"] else "MISS"
        print(f"{name}: {c['chosen']} within-2x={ok} ({c['chosen_over_best']:.2f}x)")
    for name, c in r.get("mesh2d", {}).get("corpora", {}).items():
        ok = "OK" if c["chosen_within_2x"] else "MISS"
        print(
            f"mesh2d/{name}: {c['chosen']} within-2x={ok} "
            f"({c['chosen_over_best']:.2f}x)"
        )
    if args.json:
        merge_into(args.json, r)
        print(f"-> merged planner record into {args.json}")


if __name__ == "__main__":
    main()
