import os

# 8 virtual devices so the distributed candidates are real (NOT the
# dry-run's 512); must precede the first jax import.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Planner benchmark: predicted vs measured cost per variant.

The planner's performance story must be falsifiable: for each benchmark
corpus this module (1) calibrates the hardware profile, (2) plans with the
full candidate set, (3) MEASURES one representative configuration per
variant family (the best-predicted block size of each), and (4) records
predicted-vs-measured side by side into ``BENCH_apss.json`` under
``"planner"`` — including whether the chosen plan landed within 2× of the
best measured variant (asserted by the CI schema check). A drift in the
cost model now shows up as a benchmark regression, not folklore.

    PYTHONPATH=src python -m benchmarks.bench_planner --json [PATH] [--smoke]
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402


def _corpora(*, smoke: bool):
    """Benchmark corpora: the paper's sparse regime + a dense corpus."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core.apss import normalize_rows
    from repro.data.sparse import sparse_zipfian_corpus

    # The sparse lane sits in the paper's Table-1 regime (density ≈ 0.1%,
    # m ≫ cap) where the CSR path wins on any hardware; the dense lane is a
    # fully dense corpus where the sparse representation isn't even a
    # candidate — between them the planner must flip representation.
    if smoke:
        sparse_shape, dense_shape = (1024, 8192, 8.0), (512, 256)
    else:
        sparse_shape, dense_shape = (2048, 8192, 16.0), (1024, 512)
    rng = np.random.default_rng(7)
    dense = np.abs(rng.standard_normal(dense_shape)).astype(np.float32)
    return {
        "sparse_lowdens": sparse_zipfian_corpus(*sparse_shape, seed=0),
        "dense": np.asarray(normalize_rows(jnp.asarray(dense))),
    }


def measure(
    *,
    smoke: bool = False,
    threshold: float = 0.5,
    k: int = 32,
    iters: int = 3,
    use_mesh: bool | None = None,
    max_families: int = 8,
) -> dict:
    import jax

    from benchmarks.common import time_fn
    from repro.compat import make_mesh
    from repro.planner.calibrate import calibrate
    from repro.planner.plan import execute, plan_apss

    # One-shot hardware calibration (cached to JSON keyed by device kind);
    # on virtual-device hosts this prices the "parallel" variants honestly.
    profile = calibrate(save=True)
    if use_mesh is None:
        use_mesh = not smoke
    mesh = (
        make_mesh((jax.device_count(),), ("data",))
        if use_mesh and jax.device_count() > 1
        else None
    )

    out = {
        "profile": dataclasses.asdict(profile),
        "threshold": threshold,
        "k": k,
        "mesh_devices": 1 if mesh is None else jax.device_count(),
        "corpora": {},
    }
    for name, corpus in _corpora(smoke=smoke).items():
        plan = plan_apss(
            corpus, threshold, k, mesh, profile=profile, include_kernel=False
        )
        # One measured config per variant family (its best-predicted block
        # size): block-size ties are modeled identically, so measuring all
        # of them would only add noise to the within-2× comparison. The
        # corpus is converted once per representation (prepared=True) so
        # timings cover the join the model prices, not per-call to_dense.
        from repro.planner.plan import _to_representation

        seen: set = set()
        rep_cache: dict = {}
        entries = []
        for e in plan.estimates:
            fam = (e.config.kind, e.config.schedule,
                   e.config.accumulation, e.config.sparse)
            if fam in seen or len(entries) >= max_families:
                continue
            seen.add(fam)
            if e.config.sparse not in rep_cache:
                rep_cache[e.config.sparse] = _to_representation(
                    corpus, e.config.sparse
                )
            data = rep_cache[e.config.sparse]
            us = time_fn(
                lambda cfg=e.config, d=data: execute(
                    cfg, d, threshold, k, mesh, prepared=True
                ),
                warmup=1, iters=iters,
            )
            e.measured_s = us * 1e-6
            entries.append({**e.as_dict(), "measured_us": us})
        best = min(entries, key=lambda d: d["measured_us"])
        # The planner's full operating mode is plan + autotune: the best-
        # predicted config of each of the top-3 distinct variant families
        # is microbenchmarked and the measured winner runs — exactly what
        # plan_apss(autotune=True) does. Entries are family-deduped in
        # predicted order, so the autotuned choice is the best of the
        # first three — graded against the best of EVERY measured family.
        chosen = min(entries[:3], key=lambda d: d["measured_us"])
        ratio = chosen["measured_us"] / best["measured_us"]
        out["corpora"][name] = {
            "summary": plan.summary.as_dict(),
            "chosen_predicted": plan.config.name,
            "chosen": chosen["config"],
            "autotuned": True,
            "entries": entries,
            "best_measured": best["config"],
            "chosen_over_best": ratio,
            "chosen_within_2x": ratio <= 2.0,
        }
        print(
            f"[planner] {name}: chosen {chosen['config']} "
            f"(predicted-best {plan.config.name}; "
            f"{chosen['measured_us']:.0f}us measured, "
            f"{chosen['predicted_s'] * 1e6:.0f}us predicted), "
            f"best measured {best['config']} ({best['measured_us']:.0f}us), "
            f"ratio {ratio:.2f}x"
        )
        for d in entries:
            print(
                f"    {d['config']:<44} predicted {d['predicted_s']*1e6:>9.0f}us"
                f"  measured {d['measured_us']:>9.0f}us"
                f"  wire {d['wire_bytes']/1e6:>7.2f}MB"
            )
    return out


def merge_into(path: str, r: dict) -> None:
    data = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data["planner"] = r
    with open(path, "w") as f:
        json.dump(data, f, indent=2)
        f.write("\n")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", nargs="?", const="BENCH_apss.json", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="small corpora, single-device candidates (CI)")
    ap.add_argument("--threshold", type=float, default=0.5)
    ap.add_argument("--k", type=int, default=32)
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args()

    r = measure(
        smoke=args.smoke, threshold=args.threshold, k=args.k,
        iters=2 if args.smoke else args.iters,
    )
    for name, c in r["corpora"].items():
        ok = "OK" if c["chosen_within_2x"] else "MISS"
        print(f"{name}: {c['chosen']} within-2x={ok} ({c['chosen_over_best']:.2f}x)")
    if args.json:
        merge_into(args.json, r)
        print(f"-> merged planner record into {args.json}")


if __name__ == "__main__":
    main()
