"""Sparse vs dense APSS across the paper's density regime.

The paper's corpora sit at density ≲ 1% (Table 1) — exactly where dense
tile matmuls burn MXU cycles on zeros. This suite sweeps density over the
CSR-native clustered Zipfian corpus (``data.sparse``) and compares, at each
point:

  dense-fused       the dense streaming kernel (``apss_fused``) on the
                    densified corpus — every tile, `@pl.when` mask gating
  dense-compacted   dense live-tile worklist (``apss_fused_compacted``)
  sparse-xla        inverted-index worklist + support-compacted CSR tile
                    scoring, XLA scan (the off-TPU production path)
  sparse-kernel     same worklist through the CSR tile Pallas kernel

All four must agree on the exact directed match count (asserted). Each
entry also records the realized density, the dense vs sparse (inverted
index + exact nnz) live-tile fractions, and the support width ``S`` that
replaces ``m`` in the sparse tile contraction — the quantities that make
the perf trajectory interpretable (see BENCH_apss.json schema).
"""

from __future__ import annotations

import jax

from benchmarks.common import row, time_fn

DENSITIES = (0.001, 0.01, 0.1)
K = 32
THRESHOLD = 0.4


def _corpus(n: int, m: int, dens: float, seed: int = 0):
    from repro.data.sparse import sparse_clustered_corpus

    avg = max(2.0, dens * m)
    return sparse_clustered_corpus(n, m, avg, n_clusters=8, seed=seed)


def _measure_density(sp, threshold: float, *, block: int, warmup: int, iters: int):
    import numpy as np

    from repro.core.pruning import (
        block_prune_mask,
        prune_stats,
        sparse_block_prune_mask,
    )
    from repro.core.sparse import density, pad_rows_sparse, to_dense
    from repro.kernels.apss_block.ops import apss_fused, apss_fused_compacted
    from repro.kernels.apss_block.sparse import (
        apss_sparse_compacted,
        block_support_gather,
    )

    D = jax.block_until_ready(to_dense(sp))
    spp, _ = pad_rows_sparse(sp, block)
    d_stats = prune_stats(block_prune_mask(D, D, threshold, block))
    s_stats = prune_stats(sparse_block_prune_mask(spp, spp, threshold, block))
    _, bx = block_support_gather(spp, block)

    fns = {
        "dense-fused": jax.jit(
            lambda d: apss_fused(d, d, threshold, K, block_m=block, block_n=block)
        ),
        "dense-compacted": lambda d: apss_fused_compacted(
            d, threshold, K, block_m=block
        ),
    }
    out = {
        "density": density(sp),
        "avg_nnz": float(np.asarray(sp.nnz).mean()),
        "cap": sp.cap,
        "support_width_S": int(bx.shape[-1]),
        "live_tile_fraction_dense": float(d_stats.live_fraction),
        "live_tile_fraction_sparse": float(s_stats.live_fraction),
        "variants": {},
    }
    counts = {}
    for name, fn in fns.items():
        us, res = time_fn(fn, D, warmup=warmup, iters=iters, return_result=True)
        out["variants"][name] = {"us_per_call": us}
        counts[name] = int(np.asarray(res.counts).sum())
    for name, kern in (("sparse-xla", False), ("sparse-kernel", True)):
        fn = lambda s: apss_sparse_compacted(  # noqa: E731
            s, threshold, K, block_m=block, use_kernel=kern
        )
        us, res = time_fn(fn, sp, warmup=warmup, iters=iters, return_result=True)
        out["variants"][name] = {"us_per_call": us}
        counts[name] = int(np.asarray(res.counts).sum())
    assert len(set(counts.values())) == 1, counts  # exactness across variants
    out["total_matches"] = counts["sparse-xla"]
    return out


def sweep(
    n: int = 1024,
    m: int = 8192,
    densities=DENSITIES,
    *,
    threshold: float = THRESHOLD,
    block: int = 256,
    warmup: int = 1,
    iters: int = 2,
) -> dict:
    while n % block:  # largest divisor of n not exceeding the request, so
        block -= 1    # the dense comparators' tile asserts can't fire
    out = {
        "n": n, "m": m, "k": K, "threshold": threshold, "block": block,
        "entries": [],
    }
    for dens in densities:
        sp = _corpus(n, m, dens)
        e = _measure_density(
            sp, threshold, block=block, warmup=warmup, iters=iters
        )
        e["density_requested"] = dens
        out["entries"].append(e)
    return out


def run(lines: list) -> None:
    r = sweep(256, 2048, (0.01,), block=64, warmup=1, iters=2)
    e = r["entries"][0]
    for name, v in e["variants"].items():
        lines.append(row(
            f"sparse/{name}-d0.01-n256", v["us_per_call"],
            f"live_sparse={e['live_tile_fraction_sparse']:.3f};"
            f"S={e['support_width_S']};matches={e['total_matches']}",
        ))
