"""Paper Tables 2-3: sequential all-pairs variants.

The paper compares all-pairs-0/1/2 + optimizations and finds the dense-array
variant (all-pairs-0-array) fastest. Our TPU mapping has the analogous menu:

  reference        one dense n×n einsum (all-pairs-0-array, unblocked)
  blocked-<b>      row-blocked streaming (paper §5.1.9 block processing)
  kernel-dense     Pallas apss_block, no tile pruning (interpret on CPU)
  kernel-pruned    Pallas apss_block + maxweight tile mask (partial
                   indexing/minsize at tile granularity)

Derived column: matches found / live-tile fraction (pruning effectiveness).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import bench_corpus, row, time_fn
from repro.core.apss import apss_blocked, apss_reference
from repro.core.pruning import block_prune_mask, prune_stats
from repro.kernels.apss_block.ops import apss_block_matmul

T, K = 0.4, 32


def run(lines: list) -> None:
    D = jnp.asarray(bench_corpus(1024, 768))

    ref = jax.jit(lambda d: apss_reference(d, T, K))
    us = time_fn(ref, D)
    n_matches = int(ref(D).counts.sum())
    lines.append(row("seq/reference", us, f"matches={n_matches}"))

    for b in (128, 256, 512):
        fn = jax.jit(functools.partial(apss_blocked, threshold=T, k=K, block_rows=b))
        us = time_fn(fn, D)
        assert int(fn(D).counts.sum()) == n_matches
        lines.append(row(f"seq/blocked-{b}", us, f"matches={n_matches}"))

    kd = jax.jit(
        lambda d: apss_block_matmul(
            d, d, T, auto_mask=False, block_m=256, block_n=256, block_k=256
        )
    )
    us = time_fn(kd, D)
    lines.append(row("seq/kernel-dense", us, "interpret=cpu"))

    kp = jax.jit(
        lambda d: apss_block_matmul(
            d, d, T, auto_mask=True, block_m=256, block_n=256, block_k=256
        )
    )
    us = time_fn(kp, D)
    mask = block_prune_mask(D, D, T, 256, 256)
    live = float(prune_stats(mask).live_fraction)
    lines.append(row("seq/kernel-pruned", us, f"live_tiles={live:.2f}"))

    # Streaming fused extraction: Matches straight from the kernel, O(n·k)
    # HBM (the dense variants above write the full thresholded n×n matrix).
    kf = jax.jit(
        functools.partial(
            apss_blocked, threshold=T, k=K, block_rows=256, use_kernel=True
        )
    )
    us = time_fn(kf, D)
    assert int(kf(D).counts.sum()) == n_matches
    lines.append(row("seq/kernel-fused", us, f"matches={n_matches}"))
