"""§Roofline table builder: aggregates experiments/dryrun/*.json.

Per (arch × shape × mesh): the three terms (compute/memory/collective,
seconds), dominant bottleneck, MODEL_FLOPS/HLO_FLOPs utilization ratio, and
per-device memory. Markdown to stdout; also writes
experiments/roofline_table.md for EXPERIMENTS.md inclusion.
"""

from __future__ import annotations

import glob
import json
import os


def load_results(path: str = "experiments/dryrun") -> list:
    out = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            r = json.load(fh)
        parts = os.path.basename(f)[: -len(".json")].split("__")
        r["tag"] = parts[3] if len(parts) > 3 else ""
        out.append(r)
    return out


def fmt_table(results: list, *, variants: bool = True) -> str:
    head = (
        "| arch | shape | mesh | variant | mem/dev GiB | compute ms | "
        "memory ms | collective ms | dominant | useful/HLO flops |\n"
        "|---|---|---|---|---|---|---|---|---|---|\n"
    )
    rows = []
    for r in sorted(
        results, key=lambda r: (r["arch"], r["shape"], r["mesh"], r.get("tag", ""))
    ):
        if not variants and r.get("tag"):
            continue
        t = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r.get('tag') or 'baseline'} "
            f"| {r['memory']['total_bytes']/2**30:.2f} "
            f"| {t['compute_s']*1e3:.2f} | {t['memory_s']*1e3:.2f} "
            f"| {t['collective_s']*1e3:.2f} | **{t['dominant']}** "
            f"| {r['useful_flops_ratio']:.2f} |"
        )
    return head + "\n".join(rows) + "\n"


def run(lines: list) -> None:
    results = load_results()
    if not results:
        lines.append("roofline/no-dryrun-artifacts,0.0,run launch.dryrun first")
        return
    table = fmt_table(results)
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline_table.md", "w") as f:
        f.write(table)
    dominants = {}
    for r in results:
        dominants.setdefault(r["roofline"]["dominant"], 0)
        dominants[r["roofline"]["dominant"]] += 1
    for r in sorted(
        results, key=lambda r: (r["arch"], r["shape"], r["mesh"], r.get("tag", ""))
    ):
        t = r["roofline"]
        bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
        frac = t["compute_s"] / bound if bound else 0.0
        tag = f".{r['tag']}" if r.get("tag") else ""
        lines.append(
            f"roofline/{r['arch']}.{r['shape']}.{r['mesh']}{tag},"
            f"{bound*1e6:.1f},"
            f"dominant={t['dominant']};compute_frac={frac:.2f};"
            f"useful={r['useful_flops_ratio']:.2f}"
        )
    lines.append(
        f"roofline/summary,0.0,cells={len(results)};dominants={dominants}"
    )


if __name__ == "__main__":
    table = fmt_table(load_results())
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline_table.md", "w") as f:
        f.write(table)
    print(table)
