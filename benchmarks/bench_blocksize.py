"""Paper Tables 7-8 / Fig 8: block-processing sweep.

The paper processes query vectors in blocks (1..64) to amortize collective
latency; larger blocks cut communication/barrier time until memory pressure
bites. Our ``block_rows`` is the same knob (also the MXU tile height). The
sweep reports wall time + per-device collective bytes + collective op COUNT
— the op count is the latency-amortization metric (fewer, larger transfers),
exactly the effect the paper measures.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import bench_corpus, row, time_fn
from repro.core.distributed import apss_2d, apss_vertical

T, K = 0.4, 32


def run(lines: list) -> None:
    from repro.launch.hlo_analysis import analyze

    def collective_stats(hlo):
        return analyze(hlo)["collectives"]

    D = jnp.asarray(bench_corpus(512, 768))
    from repro.compat import make_mesh

    mesh_v = make_mesh((8,), ("model",))
    mesh_2d = make_mesh((4, 2), ("data", "model"))

    for b in (16, 32, 64, 128, 256, 512):
        fn = functools.partial(
            apss_vertical, threshold=T, k=K, mesh=mesh_v,
            accumulation="compressed", block_rows=b, candidate_capacity=256,
        )
        us = time_fn(jax.jit(fn), D)
        st = collective_stats(jax.jit(fn).lower(D).compile().as_text())
        n_ops = sum(v["count"] for v in st.values())
        cbytes = sum(v["link_bytes"] for v in st.values())
        lines.append(row(
            f"blocksize/vertical-bs{b}", us,
            f"coll_ops={n_ops};coll_bytes={cbytes:.0f}",
        ))

    for b in (16, 64, 128):
        fn = functools.partial(
            apss_2d, threshold=T, k=K, mesh=mesh_2d,
            accumulation="compressed", block_rows=b, candidate_capacity=256,
        )
        us = time_fn(jax.jit(fn), D)
        st = collective_stats(jax.jit(fn).lower(D).compile().as_text())
        n_ops = sum(v["count"] for v in st.values())
        lines.append(row(f"blocksize/2d-bs{b}", us, f"coll_ops={n_ops}"))
