"""Benchmark harness — one module per paper table/figure.

- bench_sequential: paper Tables 2-3 (sequential algorithm variants)
- bench_pruning:    paper Tables 5-6 (local pruning: candidates + volume)
- bench_blocksize:  paper Tables 7-8 / Fig 8 (block-processing sweep)
- bench_parallel:   paper Figs 3-6 (distribution comparison on 8 devices)
- roofline:         §Roofline table from the dry-run artifacts
"""
